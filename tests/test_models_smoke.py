"""Per-arch smoke tests (assignment f): reduced config of the same family,
one forward/train step on CPU, asserting output shapes and finiteness —
plus prefill/decode consistency against the train-mode forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config, shape_cells
from repro.models.model import (decode_step, init_model, prefill,
                                train_logits)

ALL_ARCHS = sorted(ARCHS)


def _frontend(r, key, batch):
    if r.frontend == "vision_stub":
        return jax.random.normal(key, (batch, r.frontend_tokens, r.d_model))
    if r.frontend == "audio_stub":
        return jax.random.normal(key, (batch, r.encoder_seq, r.d_model))
    return None


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_forward_shapes_and_finite(name):
    cfg = reduced_config(ARCHS[name])
    key = jax.random.PRNGKey(0)
    params, axes = init_model(key, cfg)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, aux = train_logits(params, cfg, tokens,
                               frontend_embeds=_frontend(cfg, key, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_decreases_loss(name):
    """Two SGD steps on a tiny batch must reduce the causal LM loss."""
    cfg = dataclasses.replace(reduced_config(ARCHS[name]), dtype="float32",
                              remat="none")
    key = jax.random.PRNGKey(1)
    params, _ = init_model(key, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = _frontend(cfg, key, B)

    def loss_fn(p):
        logits, aux = train_logits(p, cfg, tokens, frontend_embeds=fe)
        tgt = tokens[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32))
        nll = -jnp.take_along_axis(lp, tgt[..., None], -1).mean()
        return nll + 0.01 * aux

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype),
                           params, grads)
    loss1 = loss_fn(params2)
    assert bool(jnp.isfinite(loss0)) and bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_consistency(name):
    """decode_step(prefill(x[:-1]), x[-1]) must equal train forward at the
    last position (f32, generous capacity so MoE drops nothing; hybrid runs
    in long-context/SWA-only mode to match its ring-cache decode)."""
    cfg = reduced_config(ARCHS[name])
    over = dict(dtype="float32", remat="none")
    if cfg.is_moe:
        over["capacity_factor"] = 8.0     # no capacity drops
    if cfg.family == "hybrid":
        over["global_attn_every"] = 0     # SWA everywhere (= decode mode)
    cfg = dataclasses.replace(cfg, **over)
    key = jax.random.PRNGKey(2)
    params, _ = init_model(key, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = _frontend(cfg, key, B)
    full, _ = train_logits(params, cfg, tokens, frontend_embeds=fe)
    lp, caches = prefill(params, cfg, tokens[:, :S - 1], S,
                         frontend_embeds=fe)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, S - 2]),
                               atol=2e-4, rtol=2e-4)
    # decode the token train saw at position S-1 (vlm prepend shifts text)
    shift = cfg.frontend_tokens if cfg.family == "vlm" else 0
    tok = tokens[:, S - 1 - shift: S - shift]
    ld, _ = decode_step(params, cfg, tok, caches, S - 1,
                        enc_out=caches.get("enc_out"))
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full[:, S - 1]),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_steps_advance(name):
    """Several decode steps run, stay finite, and caches update."""
    cfg = reduced_config(ARCHS[name])
    key = jax.random.PRNGKey(3)
    params, _ = init_model(key, cfg)
    B, S, C = 2, 8, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = _frontend(cfg, key, B)
    logits, caches = prefill(params, cfg, tokens, C, frontend_embeds=fe)
    for step in range(3):
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, caches = decode_step(params, cfg, tok, caches, S + step,
                                     enc_out=caches.get("enc_out"))
        assert bool(jnp.isfinite(logits).all())


def test_shape_cells_long_context_policy():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    runs = {n for n, c in ARCHS.items()
            if shape_cells(c)["long_500k"] is not None}
    assert runs == {"xlstm-350m", "hymba-1.5b", "mixtral-8x22b"}


def test_param_counts_match_scale():
    """Full-config parameter counts are in the advertised ballpark."""
    assert 7.0e9 < ARCHS["granite-3-8b"].param_count() < 10e9
    assert 0.9e12 < ARCHS["kimi-k2-1t-a32b"].param_count() < 1.2e12
    active = ARCHS["kimi-k2-1t-a32b"].active_param_count()
    assert 2.0e10 < active < 5.0e10          # ~32B active
    assert 1.2e11 < ARCHS["mixtral-8x22b"].param_count() < 1.8e11
    assert 0.2e9 < ARCHS["xlstm-350m"].param_count() < 0.9e9
