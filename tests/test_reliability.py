"""Reliability tier: seeded faults, ECC-aware matching, error-path parity.

Covers the §IV-C pipeline end to end: the vectorized CRC kernels against
their per-byte oracles, `FaultModel` determinism, the typed
`UncorrectableReadError` channel behaving identically on the scalar,
batched and sharded backends (below-t, above-t, header-only and body-only
corruption), reprogram clearing injected damage, retention refreshes, and
the two sweep-level contracts in miniature: a verified replay produces
zero wrong results against the analytic oracle, an unverified noisy
replay produces a nonzero wrong-op rate that voting shrinks and the
analytic sense bounds cap.
"""
import math

import numpy as np
import pytest

from repro.backend import make_backend
from repro.core.commands import Command
from repro.core.ecc import (_crc32_bytewise, _crc64_bytewise,
                            build_header_chunk, crc32, crc32_rows, crc64,
                            crc64_rows, parse_header_chunk,
                            parse_header_chunks)
from repro.core.ecc import EccConfig
from repro.core.engine import SimChipArray
from repro.reliability import (FaultModel, ReliabilityPolicy,
                               ReliabilityState, UncorrectableReadError,
                               majority_flip_prob,
                               sense_false_negative_bound,
                               sense_false_positive_bound)
from repro.frontend import RunConfig, replay
from repro.workload.ycsb import generate

BACKENDS = ("scalar", "batched", "sharded")
T_CORRECTABLE = 40


# ------------------------------------------------------------- CRC kernels
def test_crc_fold_matches_bytewise():
    rng = np.random.default_rng(0)
    # Lengths straddling the row size, incl. ragged tails and the
    # below-2-rows bytewise short-circuit.
    for n in (0, 1, 63, 64, 65, 500, 4096, 4097):
        buf = rng.integers(0, 256, n, dtype=np.uint64).astype(np.uint8)
        assert crc64(buf) == _crc64_bytewise(buf), n
        assert crc32(buf) == _crc32_bytewise(buf), n


def test_crc_rows_batch_matches_loop():
    rng = np.random.default_rng(1)
    rows = rng.integers(0, 256, (9, 173), dtype=np.uint64).astype(np.uint8)
    np.testing.assert_array_equal(
        crc64_rows(rows),
        np.array([_crc64_bytewise(r) for r in rows], dtype=np.uint64))
    np.testing.assert_array_equal(
        crc32_rows(rows),
        np.array([_crc32_bytewise(r) for r in rows], dtype=np.uint32))


def test_parse_header_chunks_batch_matches_scalar():
    chunks = np.stack([build_header_chunk(ts * 1000 + 7)
                       for ts in range(6)])
    chunks[3, 10] ^= 0xFF                        # corrupt one body byte
    batch = parse_header_chunks(chunks)
    for i, h in enumerate(batch):
        ref = parse_header_chunk(chunks[i])
        assert (h.crc, h.magic, h.timestamp_ns, h.crc_ok, h.magic_ok) == \
            (ref.crc, ref.magic, ref.timestamp_ns, ref.crc_ok, ref.magic_ok)
    assert [h.crc_ok for h in batch] == [True] * 3 + [False] + [True] * 2


# -------------------------------------------------------------- FaultModel
def test_fault_model_deterministic_and_monotonic():
    fm = FaultModel(seed=5, base_ber=1e-3, retention_days=45.0)
    draws = [fm.error_bits_for(123, 7) for _ in range(3)]
    assert draws[0] == draws[1] == draws[2]
    assert FaultModel(seed=6, base_ber=1e-3, retention_days=45.0
                      ).error_bits_for(123, 7) != draws[0] or \
        FaultModel(seed=6, base_ber=1e-3, retention_days=45.0
                   ).error_bits_for(123, 8) != fm.error_bits_for(123, 8)
    assert fm.raw_ber() > FaultModel(seed=5, base_ber=1e-3).raw_ber()
    assert FaultModel(seed=5, base_ber=1e-3, pe_cycles=6000).raw_ber() \
        > FaultModel(seed=5, base_ber=1e-3).raw_ber()


def test_fault_injection_reproducible_across_arrays():
    imgs = []
    for _ in range(2):
        arr = SimChipArray(n_chips=2, pages_per_chip=4, device_seed=3)
        for p in range(4):
            arr.program_entries(p, np.arange(1, 101, dtype=np.uint64))
        FaultModel(seed=9, base_ber=2e-4, retention_days=30.0).inject(arr)
        imgs.append([c.pages[a].raw.copy() for c in arr.chips
                     for a in sorted(c.pages)])
    for a, b in zip(*imgs):
        np.testing.assert_array_equal(a, b)


def test_analytic_sense_bounds():
    assert majority_flip_prob(1e-3, 1) == pytest.approx(1e-3)
    assert majority_flip_prob(1e-3, 3) < 1e-3
    b1 = sense_false_positive_bound(1e-3, 1)
    b3 = sense_false_positive_bound(1e-3, 3)
    assert 0.0 < b3 < b1 < 1.0
    assert sense_false_negative_bound(1e-3, 3) < \
        sense_false_negative_bound(1e-3, 1)


# ------------------------------------------- typed error channel / parity
def _reliable_backend(name: str, corrupt):
    """Identically-programmed backend with targeted corruption and a
    (noise-free) reliability tier attached."""
    arr = SimChipArray(n_chips=2, pages_per_chip=6, device_seed=3)
    keys = {p: np.arange(p * 100 + 1, p * 100 + 81, dtype=np.uint64)
            for p in range(6)}
    for p, k in keys.items():
        arr.program_entries(p, k)
    corrupt(arr)
    kw = {"use_kernel": False} if name == "sharded" else {}
    backend = make_backend(name, arr, **kw)
    # retry_fix_prob=0 pins the read-retry loop: a page that is above the
    # outer-code budget AND fails its header CRC deterministically exhausts
    # retries and surfaces UNCORRECTABLE on every backend.
    rel = ReliabilityState(ReliabilityPolicy(
        vote_k=1, ecc=EccConfig(retry_fix_prob=0.0)))
    rel.install(backend)
    return backend, rel, keys


def _outcome(fn):
    try:
        resp = fn()
    except UncorrectableReadError as e:
        return ("uncorrectable", e.page_addr)
    return ("ok", np.asarray(resp.bitmap_words).tolist())


@pytest.mark.parametrize("region,n_bits", [
    ((64, 4096), 8),                  # body-only, below t: correctable
    ((0, 64), 12),                    # header chunk: open must fall back
    ((0, 64), T_CORRECTABLE + 30),    # header dead + above t: typed error
])
def test_error_path_parity_across_backends(region, n_bits):
    def corrupt(arr):
        arr.chips[0].inject_bit_errors(
            0, n_bits, rng=np.random.default_rng(4), byte_region=region)

    outs = {}
    for name in BACKENDS:
        backend, rel, keys = _reliable_backend(name, corrupt)
        per_cmd = []
        for p in range(6):
            per_cmd.append(_outcome(
                lambda p=p: backend.search(
                    Command.search(p, int(keys[p][3])))))
        outs[name] = (per_cmd, rel.stats)
    ref_cmds, ref_stats = outs["scalar"]
    # Damage confined to page 0 of chip 0 (= global page 0): every other
    # page must still resolve to its planted single-hit bitmap.
    for verdict, _ in ref_cmds[1:]:
        assert verdict == "ok"
    if n_bits > T_CORRECTABLE:
        assert ref_cmds[0] == ("uncorrectable", 0)
    else:
        assert ref_cmds[0][0] == "ok"
    for name in BACKENDS[1:]:
        cmds, stats = outs[name]
        assert cmds == ref_cmds, name
        assert stats == ref_stats, name


def test_reprogram_clears_injected_errors():
    def corrupt(arr):
        arr.chips[0].inject_bit_errors(
            0, T_CORRECTABLE + 25, rng=np.random.default_rng(4),
            byte_region=(0, 64))

    backend, _, keys = _reliable_backend("scalar", corrupt)
    with pytest.raises(UncorrectableReadError):
        backend.search(Command.search(0, int(keys[0][0])))
    backend.submit_program(0, keys[0])
    backend.flush()
    assert backend.chips.chips[0].pages[0].injected_error_bits == 0
    resp = backend.search(Command.search(0, int(keys[0][0])))
    assert np.unpackbits(
        np.asarray(resp.bitmap_words, dtype=np.uint32).view(np.uint8)
    ).sum() == 1


# ----------------------------------------------------- functional replays
def _functional(name, wl, policy, fault, **kw):
    arr = SimChipArray(
        n_chips=2, pages_per_chip=max(wl.n_index_pages // 2 + 1, 8),
        device_seed=3)
    bkw = {"use_kernel": False} if name == "sharded" else {}
    rel = ReliabilityState(policy, fault)
    res = replay(wl, make_backend(name, arr, **bkw),
                 RunConfig.reliable(rel, burst=16, **kw))
    return res, rel


def _oracle(wl):
    return (wl.keys.astype(np.uint64) + np.uint64(1)) \
        * np.uint64(0x9E3779B97F4A7C15) | np.uint64(1)


def test_verified_replay_zero_wrong_results_and_refreshes():
    wl = generate(48, n_key_pages=4, read_ratio=1.0, alpha=0.8, seed=2)
    oracle = _oracle(wl)
    policy = ReliabilityPolicy(verify_hits=True, fallback_on_miss=True,
                               vote_k=3)
    fault = FaultModel(seed=11, base_ber=1e-4, retention_days=45.0,
                       sense_ber=2e-4)
    runs = {n: _functional(n, wl, policy, fault, fused=True)
            for n in BACKENDS}
    ref, ref_rel = runs["scalar"]
    ok = ref.read_hits & (ref.read_values == oracle)
    assert np.all(ok | ref.read_errors), "silent wrong result escaped"
    # age 45 > the 30-day refresh margin: stale pages must be rewritten
    assert ref.refreshes > 0 and ref.refreshes == ref_rel.stats.refreshes
    # Per-op outcomes are the cross-backend contract; the stats snapshot is
    # not (the kernel backends' depth-1 lazy pipeline legitimately shifts
    # which resolve observes an already-repaired page, moving a few
    # verify/fallback counts — the sweep gates outcomes, not stats).
    for name in BACKENDS[1:]:
        r, _ = runs[name]
        np.testing.assert_array_equal(r.read_values, ref.read_values)
        np.testing.assert_array_equal(r.read_hits, ref.read_hits)
        np.testing.assert_array_equal(r.read_errors, ref.read_errors)


def test_unverified_noise_measured_within_bounds():
    wl = generate(64, n_key_pages=4, read_ratio=1.0, alpha=0.8, seed=3)
    oracle = _oracle(wl)
    n = len(wl.ops)
    rates = {}
    for vote_k in (1, 3):
        policy = ReliabilityPolicy(verify_hits=False,
                                   fallback_on_miss=False, vote_k=vote_k)
        fault = FaultModel(seed=11, base_ber=0.0, sense_ber=1e-3)
        res, _ = _functional("scalar", wl, policy, fault, fused=True)
        wrong = int(np.sum(~(res.read_hits
                             & (res.read_values == oracle))))
        rates[vote_k] = wrong / n
        bound = sense_false_positive_bound(1e-3, vote_k) \
            + sense_false_negative_bound(1e-3, vote_k)
        slack = 3.0 * math.sqrt(bound * (1.0 - bound) / n)
        assert rates[vote_k] <= bound + slack, vote_k
    assert rates[1] > 0.0, "noise path not exercised"
    assert rates[3] <= rates[1], "voting must not increase the error rate"


def test_write_buffer_replay_parity_under_faults():
    wl = generate(48, n_key_pages=4, read_ratio=0.75, alpha=0.8, seed=4)
    policy = ReliabilityPolicy(verify_hits=True, fallback_on_miss=True,
                               vote_k=3)
    fault = FaultModel(seed=11, base_ber=1e-4, retention_days=45.0,
                       sense_ber=2e-4)
    runs = {}
    for name in ("scalar", "batched"):
        for buffered in (False, True):
            res, _ = _functional(name, wl, policy, fault, fused=True,
                                 write_buffer=buffered)
            runs[name, buffered] = res
    ref = runs["scalar", False]
    for (name, buffered), r in runs.items():
        np.testing.assert_array_equal(r.read_values, ref.read_values,
                                      err_msg=f"{name} buffered={buffered}")
        np.testing.assert_array_equal(r.read_hits, ref.read_hits)
        np.testing.assert_array_equal(r.read_errors, ref.read_errors)
    assert runs["batched", True].programs <= runs["batched", False].programs
