"""Per-kernel validation: shape/dtype sweeps asserting allclose vs ref.py.

Kernels run under interpret=True (CPU container); the same code lowers to
Mosaic on TPU.  Each sweep covers page counts that exercise grid padding,
multiple block sizes, and the randomized-store path.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.bits import u64_array_to_pairs, unpack_bitmap
from repro.core.page import build_page
from repro.kernels.layout import (chunk_words_to_pages, pages_to_chunk_words,
                                  pages_to_planes, planes_to_pages)
from repro.kernels.sim_search.ops import sim_search, sim_search_pages
from repro.kernels.sim_search.ref import sim_search_ref
from repro.kernels.sim_gather.ops import sim_gather
from repro.kernels.sim_gather.ref import sim_gather_ref
from repro.kernels.sim_fused.ops import sim_fused, sim_fused_lookup
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

FULL = 0xFFFFFFFFFFFFFFFF


def _random_planes(n_pages, seed=0):
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, 2**32, size=(n_pages, 512), dtype=np.uint64
                      ).astype(np.uint32)
    hi = rng.integers(0, 2**32, size=(n_pages, 512), dtype=np.uint64
                      ).astype(np.uint32)
    return lo, hi


def test_layout_roundtrips():
    rng = np.random.default_rng(1)
    pages = rng.integers(0, 256, size=(5, 4096)).astype(np.uint8)
    lo, hi = pages_to_planes(pages)
    assert np.array_equal(planes_to_pages(lo, hi), pages)
    cw = pages_to_chunk_words(pages)
    assert np.array_equal(chunk_words_to_pages(cw), pages)


# ------------------------------------------------------------- sim_search

@pytest.mark.parametrize("n_pages", [1, 3, 32, 70])
@pytest.mark.parametrize("n_queries", [1, 5])
def test_sim_search_shape_sweep(n_pages, n_queries):
    lo, hi = _random_planes(n_pages, seed=n_pages)
    rng = np.random.default_rng(n_pages + 100)
    q = rng.integers(0, 2**32, size=(n_queries, 2), dtype=np.uint64
                     ).astype(np.uint32)
    m = rng.integers(0, 2**32, size=(n_queries, 2), dtype=np.uint64
                     ).astype(np.uint32)
    out = sim_search(lo, hi, q, m, page_block=16)
    ref = sim_search_ref(lo, hi, q, m)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert out.shape == (n_queries, n_pages, 16)


@pytest.mark.parametrize("page_block", [8, 32])
def test_sim_search_block_sweep(page_block):
    lo, hi = _random_planes(64, seed=3)
    q = np.array([[lo[7, 99], hi[7, 99]]], dtype=np.uint32)  # plant a hit
    m = np.array([[FULL & 0xFFFFFFFF, FULL >> 32]], dtype=np.uint32)
    out = np.asarray(sim_search(lo, hi, q, m, page_block=page_block))
    bits = unpack_bitmap(out[0], xp=np)
    assert bits[7, 99] == 1


def test_sim_search_randomized_matches_plain():
    """Randomized store + randomized query == plain search (§IV-C1)."""
    keys = np.arange(7000, 7504, dtype=np.uint64)
    plain_pages = np.stack([
        build_page(keys + 504 * p, p, randomize=False).plain
        for p in range(4)])
    rand_pages = np.stack([
        build_page(keys + 504 * p, p, device_seed=5).raw for p in range(4)])
    out_plain = sim_search_pages(plain_pages, [7100], [FULL])
    out_rand = sim_search_pages(rand_pages, [7100], [FULL],
                                randomized=True, device_seed=5)
    np.testing.assert_array_equal(np.asarray(out_plain), np.asarray(out_rand))


def test_sim_search_mask_semantics():
    lo, hi = _random_planes(8, seed=9)
    # mask = 0 matches everything
    q = np.zeros((1, 2), dtype=np.uint32)
    m = np.zeros((1, 2), dtype=np.uint32)
    out = np.asarray(sim_search(lo, hi, q, m))
    assert unpack_bitmap(out[0], xp=np).all()


# ------------------------------------------------------------- sim_gather

@pytest.mark.parametrize("n_pages", [1, 16, 33])
@pytest.mark.parametrize("max_out", [4, 16, 64])
def test_sim_gather_shape_sweep(n_pages, max_out):
    rng = np.random.default_rng(n_pages * 7 + max_out)
    chunks = rng.integers(0, 2**32, size=(n_pages, 64, 16), dtype=np.uint64
                          ).astype(np.uint32)
    bm_u64 = rng.integers(0, 2**64, size=n_pages, dtype=np.uint64)
    bm = u64_array_to_pairs(bm_u64)
    out, cnt = sim_gather(chunks, bm, max_out=max_out, page_block=8)
    ref_out, ref_cnt = sim_gather_ref(chunks, bm, max_out)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(ref_cnt))


def test_sim_gather_order_and_content():
    chunks = np.arange(1 * 64 * 16, dtype=np.uint32).reshape(1, 64, 16)
    bm = u64_array_to_pairs(np.array([(1 << 3) | (1 << 40) | (1 << 63)],
                                     dtype=np.uint64))
    out, cnt = sim_gather(chunks, bm, max_out=8)
    out = np.asarray(out)
    assert int(np.asarray(cnt)[0]) == 3
    np.testing.assert_array_equal(out[0, 0], chunks[0, 3])
    np.testing.assert_array_equal(out[0, 1], chunks[0, 40])
    np.testing.assert_array_equal(out[0, 2], chunks[0, 63])
    assert (out[0, 3:] == 0).all()


def test_sim_gather_overflow_truncates_but_counts():
    chunks = np.ones((1, 64, 16), dtype=np.uint32)
    bm = u64_array_to_pairs(np.array([FULL], dtype=np.uint64))
    out, cnt = sim_gather(chunks, bm, max_out=4)
    assert int(np.asarray(cnt)[0]) == 64        # true count reported
    assert np.asarray(out).shape == (1, 4, 16)  # only 4 shipped


def test_sim_gather_extreme_words_exact():
    """The split-16 MXU trick must be exact for 0xFFFFFFFF etc."""
    chunks = np.full((2, 64, 16), 0xFFFFFFFF, dtype=np.uint32)
    chunks[0, 5] = 0xDEADBEEF
    bm = u64_array_to_pairs(np.array([1 << 5, 1 << 0], dtype=np.uint64))
    out, _ = sim_gather(chunks, bm, max_out=2)
    assert (np.asarray(out)[0, 0] == 0xDEADBEEF).all()
    assert (np.asarray(out)[1, 0] == 0xFFFFFFFF).all()


# ------------------------------------------------------------- sim_fused

@pytest.mark.parametrize("n_pages", [2, 17])
def test_sim_fused_matches_ref(n_pages):
    lo, hi = _random_planes(n_pages, seed=n_pages + 50)
    q = np.array([lo[0, 10], hi[0, 10]], dtype=np.uint32)
    m = np.array([0xFFFFFFFF, 0xFFFFFFFF], dtype=np.uint32)
    got = sim_fused(lo, hi, q, m, max_out=8, page_block=8)
    ref = sim_fused(lo, hi, q, m, max_out=8, use_kernel=False)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_sim_fused_gathers_matching_chunk():
    keys = np.arange(100, 604, dtype=np.uint64)
    pages = np.stack([build_page(keys, p, randomize=False).plain
                      for p in range(3)])
    lo, hi = pages_to_planes(pages)
    q = u64_array_to_pairs(np.array([307], dtype=np.uint64))[0]
    m = u64_array_to_pairs(np.array([FULL], dtype=np.uint64))[0]
    bm, g, cnt = sim_fused(lo, hi, q, m, max_out=2)
    slot = 8 + (307 - 100)
    bits = unpack_bitmap(np.asarray(bm), xp=np)
    assert (np.nonzero(bits[0])[0] == [slot]).all()
    cw = pages_to_chunk_words(pages)
    np.testing.assert_array_equal(np.asarray(g)[0, 0], cw[0, slot // 8])
    assert list(np.asarray(cnt)) == [1, 1, 1]


@pytest.mark.parametrize("n_pages,n_queries", [(2, 1), (17, 3), (8, 4)])
def test_sim_fused_multiquery_matches_ref(n_pages, n_queries):
    """The generalized fused kernel: Q queries x N pages with per-page
    flash addresses and device seeds, randomized stream in-kernel."""
    lo, hi = _random_planes(n_pages, seed=n_pages + 90)
    rng = np.random.default_rng(n_pages * 3 + n_queries)
    q = rng.integers(0, 2**32, (n_queries, 2), dtype=np.uint64
                     ).astype(np.uint32)
    m = np.full((n_queries, 2), 0xFFFFFFFF, dtype=np.uint32)
    ids = rng.integers(0, 4096, n_pages).astype(np.uint32)
    seeds = rng.integers(0, 2**31, n_pages).astype(np.uint32)
    got = sim_fused(lo, hi, q, m, max_out=4, page_block=8, randomized=True,
                    page_ids=ids, page_seeds=seeds)
    ref = sim_fused(lo, hi, q, m, max_out=4, use_kernel=False,
                    randomized=True, page_ids=ids, page_seeds=seeds)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    assert np.asarray(got[0]).shape == (n_queries, n_pages, 16)
    assert np.asarray(got[1]).shape == (n_queries, n_pages, 4, 16)


@pytest.mark.parametrize("n_rows,row_block", [(3, 4), (8, 8), (13, 4)])
def test_sim_fused_lookup_matches_ref(n_rows, row_block):
    rng = np.random.default_rng(n_rows * 11 + row_block)
    klo, khi = _random_planes(n_rows, seed=n_rows)
    vlo, vhi = _random_planes(n_rows, seed=n_rows + 1)
    # Half planted hits (copy a key-plane slot into the query), half misses.
    q = rng.integers(0, 2**32, (n_rows, 2), dtype=np.uint64
                     ).astype(np.uint32)
    for i in range(0, n_rows, 2):
        s = int(rng.integers(8, 512))
        q[i] = [klo[i, s], khi[i, s]]
    m = np.full((n_rows, 2), 0xFFFFFFFF, dtype=np.uint32)
    ids = rng.integers(0, 4096, n_rows).astype(np.uint32)
    seeds = rng.integers(0, 2**31, n_rows).astype(np.uint32)
    for randomized in (False, True):
        got = sim_fused_lookup(klo, khi, vlo, vhi, q, m,
                               row_block=row_block, randomized=randomized,
                               key_ids=ids, key_seeds=seeds)
        ref = sim_fused_lookup(klo, khi, vlo, vhi, q, m, use_kernel=False,
                               randomized=randomized, key_ids=ids,
                               key_seeds=seeds)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_sim_fused_lookup_gathers_value_chunk():
    """End-to-end semantics: the returned value words are the value page's
    chunk holding the first matching user slot; slot 512 flags a miss."""
    keys = np.arange(100, 604, dtype=np.uint64)
    kpages = np.stack([build_page(keys + 504 * p, p, randomize=False).plain
                       for p in range(3)])
    vpages = np.stack([build_page(keys * 9 + p, p, randomize=False).plain
                       for p in range(3)])
    klo, khi = pages_to_planes(kpages)
    vlo, vhi = pages_to_planes(vpages)
    probe = [100 + 13, 504 + 100 + 250, 999_999]     # hit, hit, miss
    q = u64_array_to_pairs(np.asarray(probe, dtype=np.uint64))
    m = u64_array_to_pairs(np.array([FULL] * 3, dtype=np.uint64))
    bm, val, slot = sim_fused_lookup(klo, khi, vlo, vhi, q, m, row_block=4)
    slots = np.asarray(slot)
    assert slots.tolist() == [8 + 13, 8 + 250, 512]
    cw = pages_to_chunk_words(vpages)
    np.testing.assert_array_equal(np.asarray(val)[0], cw[0, (8 + 13) // 8])
    np.testing.assert_array_equal(np.asarray(val)[1], cw[1, (8 + 250) // 8])
    assert (np.asarray(val)[2] == 0).all()
    # the raw bitmap still reports every match, header slots included
    bits = unpack_bitmap(np.asarray(bm), xp=np)
    assert bits[0, 8 + 13] == 1 and bits[2].sum() == 0


# -------------------------------------------------------- flash attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 128)])
def test_flash_attention_sweep(dtype, causal, window):
    rng = np.random.default_rng(0)
    B, S, H, HKV, D = 2, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, HKV, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, HKV, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("blocks", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_sweep(blocks):
    bq, bk = blocks
    rng = np.random.default_rng(1)
    B, S, H, HKV, D = 1, 256, 2, 1, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, HKV, D)), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_flash_attention_decode_fallback():
    """Sq=1 decode goes through the dense ref path (documented fallback)."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(2, 1, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 200, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 200, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
