"""Unit + property tests: range decomposition and BitWeaving column packing."""
import numpy as np
import pytest
# hypothesis is an optional dev dependency (requirements-dev.txt);
# skip cleanly on minimal installs so tier-1 collection stays green.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bitweaving import Column, RowCodec
from repro.core.range_query import (approximate_range, exact_range,
                                    false_positive_bound)


def test_exact_range_small():
    ks = np.arange(64, dtype=np.uint64)
    plan = exact_range(3, 20, width=6)
    exp = (ks >= 3) & (ks < 20)
    assert np.array_equal(plan.evaluate(ks), exp)


def test_exact_range_paper_example():
    """Fig 10: 2000 < salary < 7000 — our [L, U) equivalent."""
    ks = np.arange(0, 16384, dtype=np.uint64)
    plan = exact_range(2001, 7000, width=14)
    exp = (ks > 2000) & (ks < 7000)
    assert np.array_equal(plan.evaluate(ks), exp)
    # the decomposition stays compact (multi-pass §V-C, not 5000 probes)
    assert plan.n_passes <= 2 * 14


def test_approximate_range_is_superset_and_bounded():
    ks = np.arange(0, 16384, dtype=np.uint64)
    plan = approximate_range(2001, 7000, width=14)
    exp = (ks >= 2001) & (ks < 7000)
    got = plan.evaluate(ks)
    assert (got >= exp).all()
    fp = (got.sum() - exp.sum()) / exp.sum()
    assert fp <= false_positive_bound(plan, 2001, 7000, 14) + 1e-9
    assert plan.n_passes <= 2     # one include + one exclude pass


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**16 - 2), st.integers(1, 2**16))
def test_exact_range_property(lo, span):
    hi = min(lo + span, 2**16)
    if hi <= lo:
        return
    ks = np.arange(0, 2**16, dtype=np.uint64)
    plan = exact_range(lo, hi, width=16)
    exp = (ks >= lo) & (ks < hi)
    assert np.array_equal(plan.evaluate(ks), exp)
    assert plan.n_passes <= 2 * 16 - 1


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**16 - 2), st.integers(1, 2**16))
def test_approximate_range_superset_property(lo, span):
    hi = min(lo + span, 2**16)
    if hi <= lo:
        return
    ks = np.arange(0, 2**16, dtype=np.uint64)
    plan = approximate_range(lo, hi, width=16)
    exp = (ks >= lo) & (ks < hi)
    got = plan.evaluate(ks)
    assert (got >= exp).all()


def test_invalid_ranges_rejected():
    with pytest.raises(ValueError):
        exact_range(5, 5, width=8)
    with pytest.raises(ValueError):
        approximate_range(10, 5, width=8)
    with pytest.raises(ValueError):
        exact_range(0, 2**9, width=8)


# --------------------------------------------------------------- BitWeaving

def _user_codec():
    # Fig 9-style user table: gender(1) | age(7) | salary(20) | uid(32)
    return RowCodec([Column("gender", 1), Column("age", 7),
                     Column("salary", 20), Column("uid", 32)])


def test_codec_roundtrip():
    c = _user_codec()
    k = c.encode(gender=1, age=54, salary=123456, uid=0xDEAD)
    assert c.decode(k, "gender") == 1
    assert c.decode(k, "age") == 54
    assert c.decode(k, "salary") == 123456
    assert c.decode(k, "uid") == 0xDEAD


def test_codec_vector_roundtrip():
    c = _user_codec()
    rng = np.random.default_rng(0)
    rows = {"gender": rng.integers(0, 2, 100), "age": rng.integers(0, 128, 100),
            "salary": rng.integers(0, 2**20, 100),
            "uid": rng.integers(0, 2**32, 100)}
    keys = c.encode_rows(rows)
    for name in rows:
        assert np.array_equal(c.decode_rows(keys, name),
                              np.asarray(rows[name], dtype=np.uint64))


def test_codec_equals_predicate_fig9():
    """Paper Fig 9: select all female users via a masked point query."""
    c = _user_codec()
    rng = np.random.default_rng(1)
    rows = {"gender": rng.integers(0, 2, 500), "age": rng.integers(0, 128, 500),
            "salary": rng.integers(0, 2**20, 500),
            "uid": np.arange(500)}
    keys = c.encode_rows(rows)
    mq = c.equals("gender", 1)
    got = mq.matches(keys)
    assert np.array_equal(got, rows["gender"] == 1)


def test_codec_range_predicate_fig10():
    """Paper Fig 10: 2000 < salary < 7000 over the packed keys."""
    c = _user_codec()
    rng = np.random.default_rng(2)
    rows = {"gender": rng.integers(0, 2, 2000),
            "age": rng.integers(0, 128, 2000),
            "salary": rng.integers(0, 10000, 2000),
            "uid": np.arange(2000)}
    keys = c.encode_rows(rows)
    exp = (rows["salary"] > 2000) & (rows["salary"] < 7000)
    exact = c.range("salary", 2001, 7000, exact=True).evaluate(keys)
    assert np.array_equal(exact, exp)
    approx = c.range("salary", 2001, 7000, exact=False).evaluate(keys)
    assert (approx >= exp).all()        # superset, to be refined by the host


def test_codec_width_overflow_rejected():
    with pytest.raises(ValueError):
        RowCodec([Column("a", 40), Column("b", 40)])
    c = _user_codec()
    with pytest.raises(ValueError):
        c.encode(gender=2)


def test_big_endian_order_preservation():
    """MSB-first packing preserves order on the sort column (salary-major)."""
    c = RowCodec([Column("salary", 20), Column("uid", 32)])
    k1 = c.encode(salary=100, uid=0xFFFFFFFF)
    k2 = c.encode(salary=101, uid=0)
    assert k1 < k2
