"""Unit tests: SimChip functional model — commands, latch pipeline, integrity."""
import numpy as np
import pytest

from repro.core import (Command, EccConfig, OpenVerdict, SimChip,
                        SimChipArray, pair_to_u64, unpack_bitmap)
from repro.core.bits import chunk_bitmap_from_slot_bitmap
from repro.core.page import SLOTS_PER_CHUNK


@pytest.fixture
def chip():
    c = SimChip(n_pages=32, device_seed=11)
    c.program_entries(2, np.arange(5000, 5504, dtype=np.uint64),
                      timestamp_ns=1)
    return c


def test_search_finds_exact_slot(chip):
    r = chip.search(Command.search(2, 5123))
    assert r.match_count == 1
    slot = int(np.nonzero(unpack_bitmap(r.bitmap_words, 512))[0][0])
    assert slot == SLOTS_PER_CHUNK + (5123 - 5000)


def test_search_miss(chip):
    assert chip.search(Command.search(2, 999_999)).match_count == 0


def test_search_with_mask_matches_prefix(chip):
    # keys 5000..5503; mask off the low 9 bits -> match whole aligned block
    mask = 0xFFFFFFFFFFFFFE00
    r = chip.search(Command.search(2, 5120, mask))
    keys = np.arange(5000, 5504, dtype=np.uint64)
    expected = int(((keys & np.uint64(mask)) == (5120 & mask)).sum())
    assert r.match_count == expected


def test_gather_returns_derandomized_chunks(chip):
    r = chip.search(Command.search(2, 5123))
    cb = chunk_bitmap_from_slot_bitmap(r.bitmap_words)
    g = chip.gather(Command.gather(2, pair_to_u64(*cb)))
    assert g.chunk_ids.size == 1 and g.parity_ok.all()
    # the slot's bytes inside the gathered chunk decode back to the key
    slot = SLOTS_PER_CHUNK + (5123 - 5000)
    off = (slot % 8) * 8
    val = int.from_bytes(bytes(g.chunks[0][off:off + 8]), "little")
    assert val == 5123


def test_latch_pipeline_overlap(chip):
    chip.program_entries(3, np.arange(10, dtype=np.uint64))
    chip.page_open(2)
    chip.page_close(2)
    # opening page 3 while page 2 is matched from L2 counts as pipelined
    chip.page_open(3)
    assert chip.counters.pipelined_opens >= 1
    r = chip.search(Command.search(2, 5000))     # L2 still holds page 2
    assert r.match_count == 1


def test_page_close_requires_l1(chip):
    with pytest.raises(RuntimeError):
        chip.page_close(9)


def test_body_errors_are_invisible_to_optimistic_check(chip):
    """The acknowledged §IV-C2 risk: body-only damage passes page_open."""
    chip.inject_bit_errors(2, 3, byte_region=(64, 4096))
    res, _ = chip.page_open(2, now_ns=2)
    assert res.verdict is OpenVerdict.CLEAN
    # ...but the concatenated inner code catches it at gather time.
    chip.page_close(2)
    g = chip.gather(Command.gather(2, 0xFFFFFFFFFFFFFFFF))
    assert not g.parity_ok.all()


def test_header_errors_trigger_fallback_and_repair(chip):
    chip.inject_bit_errors(2, 4, byte_region=(0, 64))
    res, _ = chip.page_open(2, now_ns=2)
    assert res.verdict is OpenVerdict.FALLBACK_ECC
    assert chip.counters.open_fallbacks == 1
    assert chip.search(Command.search(2, 5123)).match_count == 1


def test_uncorrectable_after_retries():
    c = SimChip(n_pages=4, ecc_cfg=EccConfig(t_correctable=2,
                                             max_read_retries=2,
                                             retry_fix_prob=0.0))
    c.program_entries(0, np.arange(4, dtype=np.uint64))
    c.inject_bit_errors(0, 30, byte_region=(0, 64))
    res, _ = c.page_open(0)
    assert res.verdict is OpenVerdict.UNCORRECTABLE


def test_read_full_roundtrip(chip):
    plain = chip.read_full(2).plain
    from repro.core.page import entries_from_plain
    assert np.array_equal(entries_from_plain(plain, 504),
                          np.arange(5000, 5504, dtype=np.uint64))


def test_unprogrammed_page_raises(chip):
    with pytest.raises(KeyError):
        chip.read_full(31)


def test_chip_array_routing():
    arr = SimChipArray(n_chips=4, pages_per_chip=8)
    for p in range(16):
        arr.program_entries(p, np.array([p * 1000 + 1], dtype=np.uint64))
    for p in range(16):
        assert arr.search(Command.search(p, p * 1000 + 1)).match_count == 1
    # chips got striped evenly
    assert all(len(c.pages) == 4 for c in arr.chips)


def test_header_aliasing_stripped_by_software(chip):
    """A query equal to a zeroed header field aliases into chunk 0; the
    software-side mask_header_slots strips it (page.py helper)."""
    from repro.core.page import mask_header_slots
    chip.program_entries(4, np.array([0], dtype=np.uint64), timestamp_ns=0)
    r = chip.search(Command.search(4, 0))
    assert r.match_count > 1          # raw chip result includes header hits
    cleaned = mask_header_slots(r.bitmap_words)
    idx = np.nonzero(unpack_bitmap(cleaned, 512))[0]
    assert list(idx) == [SLOTS_PER_CHUNK]   # only the real entry survives


def test_empty_mask_matches_everything(chip):
    """mask==0: every slot matches (the redistribution full-select §V-D)."""
    r = chip.search(Command.search(2, 0, 0))
    assert r.match_count == 512
