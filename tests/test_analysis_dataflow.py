"""Dataflow engine (repro.analysis.dataflow): CFG construction, the
forward solver, suffix-dimension inference and call-graph summaries."""
import ast
import textwrap

from repro.analysis.contracts import parse_module
from repro.analysis.dataflow import Test as CondTest
from repro.analysis.dataflow import (Bind, ProjectIndex, build_cfg, calls_in,
                                     is_flush_name, is_seed_name, join_envs,
                                     looped_call_ids, suffix_dim)


def _fn(src: str) -> ast.FunctionDef:
    tree = ast.parse(textwrap.dedent(src))
    return next(n for n in ast.walk(tree)
                if isinstance(n, ast.FunctionDef))


def _mod(tmp_path, src: str, name="m.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return parse_module(p, tmp_path)


# ------------------------------------------------------------------ suffixes
def test_suffix_dimension_inference():
    assert suffix_dim("pcie_bytes") == "bytes"
    assert suffix_dim("PAGE_BYTES") == "bytes"       # constants too
    assert suffix_dim("t_read_ns") == "ns"
    assert suffix_dim("energy_pj") == "pj"
    assert suffix_dim("zipf_probs") == "prob"        # plural normalizes
    assert suffix_dim("ns") == "ns"                  # bare suffix
    assert suffix_dim("burns") is None               # no _ boundary
    assert suffix_dim("nsq") is None                 # suffix only
    assert suffix_dim("latency") is None
    assert suffix_dim(None) is None


def test_seed_and_flush_name_predicates():
    assert is_seed_name("seed") and is_seed_name("device_seed")
    assert is_seed_name("seed_root") and is_seed_name("entropy")
    assert not is_seed_name("seedling") and not is_seed_name("reseeded")
    assert is_flush_name("flush") and is_flush_name("flush_writes")
    assert is_flush_name("_drain") and is_flush_name("resolve_burst")
    assert not is_flush_name("flushed") and not is_flush_name("result")


# ----------------------------------------------------------------------- CFG
def test_cfg_if_else_join():
    fn = _fn("""
        def f(x):
            a = 1
            if x:
                b = 2
            else:
                b = 3
            return b
    """)
    cfg = build_cfg(fn)
    # every statement lands in exactly one block
    assert cfg.stmt_count() == 5   # a=1, Test(x), b=2, b=3, return
    # the entry block branches two ways; both arms rejoin in one block
    succs = cfg.blocks[0].succs
    assert len(succs) == 2
    joins = [b.idx for b in cfg.blocks
             if any(isinstance(s, ast.Return) for s in b.stmts)]
    assert len(joins) == 1


def test_cfg_loop_back_edge():
    fn = _fn("""
        def f(xs):
            total = 0
            for x in xs:
                total += x
            return total
    """)
    cfg = build_cfg(fn)
    header = next(b for b in cfg.blocks
                  if any(isinstance(s, Bind) for s in b.stmts))
    body = next(b for b in cfg.blocks
                if any(isinstance(s, ast.AugAssign) for s in b.stmts))
    assert header.idx in body.succs          # the back edge
    assert len(header.succs) == 2            # body + after


def test_cfg_while_and_break_terminate_blocks():
    fn = _fn("""
        def f(q):
            while True:
                item = q.get()
                if item is None:
                    break
            return item
    """)
    cfg = build_cfg(fn)
    header = next(b for b in cfg.blocks
                  if any(isinstance(s, CondTest) for s in b.stmts))
    after = next(b for b in cfg.blocks
                 if any(isinstance(s, ast.Return) for s in b.stmts))
    preds = [b.idx for b in cfg.blocks if after.idx in b.succs]
    # reachable via the loop exit edge AND via break
    assert len(preds) >= 2
    assert header.succs                      # header always has successors


def test_cfg_try_handler_edges():
    fn = _fn("""
        def f(t):
            try:
                r = t.result()
            except IOError:
                r = None
            return r
    """)
    cfg = build_cfg(fn)
    handler = next(b for b in cfg.blocks for s in b.stmts
                   if isinstance(s, ast.Assign)
                   and isinstance(s.value, ast.Constant))
    preds = [b.idx for b in cfg.blocks if handler.idx in b.succs]
    # reachable both by skipping the body and after the body ran
    assert len(preds) >= 2


def test_calls_in_evaluation_order_and_scope():
    st = ast.parse("x = outer(inner()).result()").body[0]
    names = [c.func.id if isinstance(c.func, ast.Name) else c.func.attr
             for c in calls_in(st)]
    assert names == ["inner", "outer", "result"]
    # nested defs and lambdas are opaque
    st2 = ast.parse("f = lambda: hidden()").body[0]
    assert list(calls_in(st2)) == []


def test_looped_call_ids_marks_loops_and_comprehensions():
    fn = _fn("""
        def f(backend, cmds):
            once = backend.submit_search(cmds[0])
            many = [backend.submit_search(c) for c in cmds]
            for c in cmds:
                backend.submit_gather(c)
    """)
    looped = looped_call_ids(fn)
    calls = {c.func.attr: c for c in ast.walk(fn)
             if isinstance(c, ast.Call)
             and isinstance(c.func, ast.Attribute)}
    assert id(calls["submit_gather"]) in looped
    once, comp = [c for c in ast.walk(fn) if isinstance(c, ast.Call)
                  and isinstance(c.func, ast.Attribute)
                  and c.func.attr == "submit_search"]
    assert (id(once) in looped) != (id(comp) in looped)


def test_join_envs_is_keywise_union():
    a = {"x": frozenset({"ns"})}
    b = {"x": frozenset({"pj"}), "y": frozenset({"bytes"})}
    j = join_envs(a, b)
    assert j == {"x": frozenset({"ns", "pj"}), "y": frozenset({"bytes"})}
    assert join_envs(None, b) == b


# ------------------------------------------------------------- summaries
def test_return_dims_summary_propagates_through_calls(tmp_path):
    mod = _mod(tmp_path, """
        def total_ns(a_ns, b_ns):
            return a_ns + b_ns

        def doubled(a_ns, b_ns):
            return total_ns(a_ns, b_ns)
    """)
    idx = ProjectIndex.get()
    view = idx.with_module(mod)
    total, doubled = view._local
    assert view.return_dims(total) == frozenset({"ns"})
    # the caller's summary flows through the callee's summary
    assert view.return_dims(doubled) == frozenset({"ns"})


def test_returns_seeded_summary(tmp_path):
    mod = _mod(tmp_path, """
        def derive(base):
            return 0xFEED + base

        def launder(base):
            return base
    """)
    view = ProjectIndex.get().with_module(mod)
    derive, launder = view._local
    assert view.returns_seeded(derive) is True
    assert view.returns_seeded(launder) is False


def test_may_flush_summary_skips_result(tmp_path):
    mod = _mod(tmp_path, """
        def helper(backend):
            backend.flush()

        def indirect(backend):
            helper(backend)

        def via_result_only(ticket):
            return ticket.result()
    """)
    view = ProjectIndex.get().with_module(mod)
    helper, indirect, via_result = view._local
    assert view.may_flush(helper) is True
    assert view.may_flush(indirect) is True       # transitive
    # .result() auto-flushes at runtime, but summarizing it as a flush
    # would launder the exact anti-pattern SIM009 polices
    assert view.may_flush(via_result) is False


def test_leaves_pending_summary(tmp_path):
    mod = _mod(tmp_path, """
        def stages(backend, cmd):
            return backend.submit_search(cmd)

        def settled(backend, cmd):
            t = backend.submit_search(cmd)
            backend.flush()
            return t
    """)
    view = ProjectIndex.get().with_module(mod)
    stages, settled = view._local
    assert view.leaves_pending(stages) is True
    assert view.leaves_pending(settled) is False


def test_recursive_summaries_terminate(tmp_path):
    mod = _mod(tmp_path, """
        def ping(n):
            return pong(n - 1)

        def pong(n):
            return ping(n - 1)
    """)
    view = ProjectIndex.get().with_module(mod)
    ping, _ = view._local
    # the cycle guard bottoms out instead of recursing forever
    assert view.return_dims(ping) == frozenset()
    assert view.returns_seeded(ping) is False


def test_project_index_knows_the_real_tree():
    idx = ProjectIndex.get()
    # spot-check: the timeline adapter's flush observer is indexed
    names = {fi.qualname for fi in idx.by_name.get("observe_flush", [])}
    assert "BurstTimeline.observe_flush" in names
    # and method call_params drop self for attribute-form calls
    fi = next(f for f in idx.by_name["observe_flush"]
              if f.qualname == "BurstTimeline.observe_flush")
    call = ast.parse("tl.observe_flush(bursts)", mode="eval").body
    assert fi.call_params(call)[0] == "bursts"
