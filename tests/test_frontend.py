"""Event-driven frontend: RunConfig/RunReport API + event-loop contracts.

Contracts held here:

  * ``RunConfig`` — frozen, validated at construction (bad enums, event
    knobs in serial mode, poisson without a rate all refuse); presets
    build the documented shapes (the ``run_functional`` shim is gone —
    ``repro.frontend.replay`` is the only functional entry point);
  * ``RunReport`` — one schema for all three executors, legacy flat
    aliases reading through to the nested sections;
  * **bit-parity anchor** — ``RunConfig.event_serial()`` (one stream,
    zero inter-arrival, FIFO) replays bit-identically to
    ``mode="serial"`` across scalar/batched/sharded x split/fused x
    buffered/reliability configs: same values, hits, errors, programs
    AND the same flush grouping (reliability epochs depend on it);
  * **determinism** — same seeds => identical event trace and report;
  * **NCQ bound** — queued + inflight never exceeds ``ncq_depth`` for
    any arrival trace (hypothesis property over traces);
  * **scheduling** — on a crafted program-backlog trace, FIFO reads
    queue behind the die-program backlog while read_priority reads
    program-suspend past it;
  * **device-fault tier** — an empty fault schedule with replicas=2 is
    still bit-identical to the plain serial replay (parity anchor); a
    dead chip fails reads over to replicas with results bit-identical
    to the healthy replay (zero wrong results); without replicas the
    same outage degrades to typed per-op errors, never wrong data; and
    same seed + same fault schedule => byte-identical RunReport
    (hypothesis property over schedules).
"""
import numpy as np
import pytest

from repro.backend import make_backend
from repro.backend.sharded import ShardedSsdBackend
from repro.core.engine import SimChipArray
from repro.frontend import (EventLoop, RunConfig, RunReport, replay)
from repro.reliability import (FaultModel, FaultSchedule,
                               ReliabilityPolicy, ReliabilityState)
from repro.workload.ycsb import KEYS_PER_PAGE, Workload, generate, \
    value_page_of


# --------------------------------------------------------------------------
# RunConfig validation + presets
# --------------------------------------------------------------------------

def test_runconfig_is_frozen_and_validated():
    cfg = RunConfig(burst=16, fused=True)
    with pytest.raises(Exception):      # frozen dataclass
        cfg.burst = 32
    with pytest.raises(ValueError):
        RunConfig(mode="turbo")
    with pytest.raises(ValueError):
        RunConfig(scheduler="lifo")
    with pytest.raises(ValueError):
        RunConfig(burst=0)
    with pytest.raises(ValueError):
        RunConfig(write_buffer="yes")


def test_runconfig_event_knobs_refused_in_serial_mode():
    for kw in (dict(concurrency=4), dict(scheduler="read_priority"),
               dict(arrival="poisson", arrival_rate_qps=1e5)):
        with pytest.raises(ValueError):
            RunConfig(**kw)
    with pytest.raises(ValueError):      # poisson needs a positive rate
        RunConfig(mode="event", arrival="poisson")
    with pytest.raises(ValueError):      # trace needs times
        RunConfig(mode="event", arrival="trace")
    with pytest.raises(ValueError):      # rate only applies to poisson
        RunConfig(mode="event", arrival_rate_qps=1e5)


def test_runconfig_presets():
    assert RunConfig.eager() == RunConfig()
    b = RunConfig.buffered(write_high_water=4)
    assert b.write_buffer is True and b.write_high_water == 4
    rel = ReliabilityState(ReliabilityPolicy(), FaultModel(seed=1))
    assert RunConfig.reliable(rel).reliability is rel
    with pytest.raises(ValueError):
        RunConfig.reliable(None)
    o = RunConfig.open_loop(2e5, concurrency=8)
    assert o.mode == "event" and o.arrival == "poisson"
    assert o.scheduler == "read_priority" and o.arrival_rate_qps == 2e5
    e = RunConfig.event_serial(burst=8)
    assert (e.mode, e.concurrency, e.arrival, e.scheduler) \
        == ("event", 1, "zero", "fifo")
    assert e.with_(fused=True).fused and not e.fused


def test_runconfig_trace_times_normalized():
    cfg = RunConfig(mode="event", arrival="trace",
                    arrival_times_ns=[0, 10, 20])
    assert cfg.arrival_times_ns == (0.0, 10.0, 20.0)
    with pytest.raises(ValueError):
        RunConfig(mode="event", arrival="trace", arrival_times_ns=[-1.0])


# --------------------------------------------------------------------------
# RunReport shape
# --------------------------------------------------------------------------

def _mk(name="scalar", n_chips=4, pages=32, **kw):
    return make_backend(name, SimChipArray(
        n_chips=n_chips, pages_per_chip=pages, device_seed=3), **kw)


def test_run_functional_shim_is_gone():
    # The deprecation cycle promised in the shim's docstring is over:
    # repro.frontend.replay is the one functional entry point.
    import repro.workload.runner as runner
    assert not hasattr(runner, "run_functional")


def test_runreport_legacy_aliases_read_nested_sections():
    wl = generate(120, n_key_pages=4, read_ratio=0.7, alpha=0.5, seed=2)
    r = replay(wl, _mk(), RunConfig(burst=16))
    assert r.n_reads == r.counters.reads > 0
    assert r.flushes == r.counters.flushes
    assert r.programs == r.counters.programs == r.n_writes
    assert r.sim_makespan_ns == r.latency.makespan_ns
    assert r.sim_energy_pj == r.energy.total_pj
    assert r.n_read_errors == r.reliability.n_read_errors == 0


def test_analytic_run_returns_runreport():
    from repro.flash.params import DEFAULT_PARAMS
    from repro.workload.runner import run
    wl = generate(800, n_key_pages=16, read_ratio=0.7, alpha=0.5, seed=4)
    r = run(wl, params=DEFAULT_PARAMS, system="sim", cache_coverage=0.25)
    assert isinstance(r, RunReport) and r.source == "analytic"
    assert r.qps == r.latency.qps > 0
    assert r.read_median_ns == r.latency.read_p50_ns > 0
    assert r.senses == r.counters.senses > 0
    assert r.energy_pj == r.energy.total_pj > 0


# --------------------------------------------------------------------------
# Bit-parity anchor: event_serial == serial
# --------------------------------------------------------------------------

def _assert_parity(rs, re):
    np.testing.assert_array_equal(rs.read_values, re.read_values)
    np.testing.assert_array_equal(rs.read_hits, re.read_hits)
    if rs.scan_counts is not None or re.scan_counts is not None:
        np.testing.assert_array_equal(rs.scan_counts, re.scan_counts)
    if rs.read_errors is not None or re.read_errors is not None:
        np.testing.assert_array_equal(rs.read_errors, re.read_errors)
    assert rs.programs == re.programs
    assert rs.flushes == re.flushes          # same burst grouping
    assert rs.write_flushes == re.write_flushes
    assert rs.buffer_read_hits == re.buffer_read_hits
    assert rs.kernel_launches == re.kernel_launches
    assert rs.refreshes == re.refreshes


@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("buffered", [False, True])
@pytest.mark.parametrize("name", ["scalar", "batched", "sharded"])
def test_event_serial_bit_parity(name, fused, buffered):
    wl = generate(300, n_key_pages=8, read_ratio=0.5, alpha=0.9, seed=7,
                  scan_ratio=0.05)
    kw = dict(burst=32, fused=fused)
    if buffered:
        kw.update(write_buffer=True, write_high_water=4)

    def mk():
        if name == "sharded":
            return ShardedSsdBackend.from_geometry(
                channels=2, dies_per_channel=2,
                pages_per_chip=max(wl.n_index_pages // 4 + 1, 8),
                device_seed=3)
        return _mk(name, n_chips=4,
                   pages=max(wl.n_index_pages // 4 + 1, 8))

    _assert_parity(replay(wl, mk(), RunConfig(**kw)),
                   replay(wl, mk(), RunConfig.event_serial(**kw)))


@pytest.mark.parametrize("buffered", [False, True])
def test_event_serial_bit_parity_reliability(buffered):
    wl = generate(200, n_key_pages=4, read_ratio=0.6, alpha=0.9, seed=9)
    kw = dict(burst=16, fused=True)
    if buffered:
        kw.update(write_buffer=True, write_high_water=4)

    def rel():
        return ReliabilityState(
            ReliabilityPolicy(verify_hits=True, fallback_on_miss=True),
            FaultModel(seed=11, base_ber=1e-4, retention_days=45.0,
                       sense_ber=2e-4))

    def mk():
        return make_backend("scalar", SimChipArray(
            n_chips=2, pages_per_chip=max(wl.n_index_pages // 2 + 1, 8),
            device_seed=3))

    rs = replay(wl, mk(), RunConfig.reliable(rel(), **kw))
    re = replay(wl, mk(),
                RunConfig.event_serial(reliability=rel(), **kw))
    _assert_parity(rs, re)
    assert rs.refreshes > 0          # the refresh path actually ran


# --------------------------------------------------------------------------
# Determinism
# --------------------------------------------------------------------------

def test_event_loop_deterministic_trace_and_report():
    wl = generate(400, n_key_pages=8, read_ratio=0.5, alpha=0.9, seed=1)
    cfg = RunConfig.open_loop(3e5, concurrency=4, burst=32, seed=12,
                              write_buffer=True, write_high_water=4,
                              record_trace=True)
    a = replay(wl, _mk(pages=16), cfg)
    b = replay(wl, _mk(pages=16), cfg)
    assert a.trace == b.trace and len(a.trace) > 0
    np.testing.assert_array_equal(a.read_values, b.read_values)
    assert a.latency.read_p99_ns == b.latency.read_p99_ns
    assert a.counters == b.counters
    # a different seed moves the arrivals -> different trace
    c = replay(wl, _mk(pages=16), cfg.with_(seed=13))
    assert c.trace != a.trace


def test_event_counters_account_for_every_op():
    wl = generate(300, n_key_pages=8, read_ratio=0.6, alpha=0.9, seed=2)
    r = replay(wl, _mk(pages=16),
               RunConfig.open_loop(3e5, concurrency=4, ncq_depth=16,
                                   burst=16))
    c = r.counters
    assert c.admitted + c.admission_waits == len(wl.ops)
    assert c.ncq_peak <= 16
    assert c.dispatches > 0 and c.events >= len(wl.ops)
    assert r.latency.qps > 0 and r.latency.makespan_ns > 0
    assert len(r.latency.read_latencies_ns) == c.reads


# --------------------------------------------------------------------------
# NCQ depth bound: hypothesis property over arrival traces
# --------------------------------------------------------------------------

def test_ncq_depth_bound_property():
    hypothesis = pytest.importorskip("hypothesis")
    given = hypothesis.given
    st = hypothesis.strategies

    wl = generate(60, n_key_pages=4, read_ratio=0.5, alpha=0.9, seed=5)

    @given(times=st.lists(st.floats(min_value=0.0, max_value=2e6,
                                    allow_nan=False),
                          min_size=60, max_size=60),
           depth=st.integers(min_value=1, max_value=8),
           sched=st.sampled_from(["fifo", "read_priority", "fair_share"]))
    @hypothesis.settings(max_examples=25, deadline=None)
    def prop(times, depth, sched):
        loop = EventLoop(wl, _mk(pages=16), RunConfig(
            mode="event", arrival="trace", arrival_times_ns=times,
            concurrency=3, scheduler=sched, ncq_depth=depth, burst=8,
            write_buffer=True, write_high_water=4))
        r = loop.run()
        assert loop.ncq_peak <= depth
        assert r.counters.admitted + r.counters.admission_waits == 60
        assert r.counters.reads + r.counters.writes \
            + r.counters.scans == 60

    prop()


def test_ncq_depth_bound_seeded_traces():
    """No-hypothesis fallback: the same bound over seeded random traces,
    so the invariant is exercised even where hypothesis is absent."""
    wl = generate(60, n_key_pages=4, read_ratio=0.5, alpha=0.9, seed=5)
    for seed in range(6):
        rng = np.random.default_rng(seed)
        times = np.sort(rng.uniform(0.0, 2e6, 60)).tolist()
        depth = int(rng.integers(1, 9))
        sched = ["fifo", "read_priority", "fair_share"][seed % 3]
        loop = EventLoop(wl, _mk(pages=16), RunConfig(
            mode="event", arrival="trace", arrival_times_ns=times,
            concurrency=3, scheduler=sched, ncq_depth=depth, burst=8,
            write_buffer=True, write_high_water=4))
        r = loop.run()
        assert loop.ncq_peak <= depth, (seed, sched, depth)
        assert r.counters.admitted + r.counters.admission_waits == 60


# --------------------------------------------------------------------------
# Scheduling: read-priority bypasses the program backlog, FIFO queues
# --------------------------------------------------------------------------

def _backlog_workload_and_times(n_key_pages=2):
    """Ten writes land a program backlog on every die, then ten reads
    arrive while the programs are still in flight (t_program = 80 us)."""
    writes = list(range(10))                      # keys on page 0
    reads = [k + KEYS_PER_PAGE for k in range(10)]  # keys on page 1
    keys = np.asarray(writes + reads, dtype=np.int64)
    ops = np.asarray([1] * 10 + [0] * 10, dtype=np.uint8)
    kp = (keys // KEYS_PER_PAGE).astype(np.int32)
    vp = value_page_of(kp, n_key_pages).astype(np.int32)
    wl = Workload(ops=ops, key_pages=kp, value_pages=vp, alpha=0.0,
                  read_ratio=0.5, n_index_pages=2 * n_key_pages,
                  keys=keys)
    # Writes at t=0, reads 1 us later — well inside the 80 us programs.
    times = [0.0] * 10 + [1_000.0] * 10
    return wl, times


@pytest.mark.parametrize("sched,expect_stalled", [
    ("fifo", True), ("read_priority", False), ("fair_share", False)])
def test_read_priority_bypasses_program_backlog(sched, expect_stalled):
    wl, times = _backlog_workload_and_times()
    r = replay(wl, _mk(n_chips=2, pages=8), RunConfig(
        mode="event", arrival="trace", arrival_times_ns=times,
        scheduler=sched, burst=16, ncq_depth=32))
    assert r.read_hits.sum() == 10 and r.programs == 10
    p50 = r.latency.read_p50_ns
    # t_program = 80 us: FIFO reads queue behind the die backlog, so
    # their latency carries a program-sized wait; read-priority reads
    # program-suspend past it and finish in sense+bus time.
    assert (p50 > 50_000.0) == expect_stalled, p50


def test_fifo_vs_read_priority_same_totals_different_timing():
    """Above concurrency 1 the policies may legitimately reorder reads
    across writes from other streams (real NCQ semantics — individual
    read VALUES can differ; only the serial anchor is bit-exact), but
    the op accounting must agree and the FIFO tail must be worse."""
    wl = generate(400, n_key_pages=8, read_ratio=0.5, alpha=0.9, seed=3)
    reports = {}
    for sched in ("fifo", "read_priority"):
        reports[sched] = replay(wl, _mk(pages=16), RunConfig(
            mode="event", arrival="zero", concurrency=2, scheduler=sched,
            burst=32, write_buffer=True, write_high_water=4))
    fifo, rp = reports["fifo"], reports["read_priority"]
    # Functional totals agree (ordering may differ per policy) ...
    assert fifo.counters.reads == rp.counters.reads
    assert fifo.counters.writes == rp.counters.writes
    assert fifo.programs == rp.programs
    # ... but the FIFO tail carries the program waits.
    assert fifo.latency.read_p99_ns > rp.latency.read_p99_ns


# --------------------------------------------------------------------------
# Device-fault tier: replica parity anchor, failover, chaos determinism
# --------------------------------------------------------------------------

def _mk_replicated(wl, replicas=2):
    """Sharded backend with replica striping and spare headroom for the
    replica copies plus grown-bad-block remaps."""
    per_chip = (wl.n_index_pages // 4 + 1) * (replicas + 1)
    return ShardedSsdBackend(
        SimChipArray(n_chips=4, pages_per_chip=per_chip, device_seed=3),
        use_kernel=False, interpret=True, replicas=replicas)


@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("buffered", [False, True])
def test_replica_event_serial_bit_parity(fused, buffered):
    """Fault-free parity anchor: replicas=2 plus an attached (empty)
    fault schedule must not perturb a single bit of the replay — the
    whole fault tier is latency/bookkeeping until a fault actually
    fires."""
    wl = generate(300, n_key_pages=8, read_ratio=0.5, alpha=0.9, seed=7,
                  scan_ratio=0.05)
    kw = dict(burst=32, fused=fused)
    if buffered:
        kw.update(write_buffer=True, write_high_water=4)
    rs = replay(wl, _mk_replicated(wl), RunConfig(**kw))
    re = replay(wl, _mk_replicated(wl), RunConfig.event_serial(
        faults=FaultSchedule.healthy(seed=7), **kw))
    _assert_parity(rs, re)
    # the tier was live (replica mirrors programmed), yet fired nothing
    f = re.faults
    assert f.replica_programs > 0
    assert (f.timeouts, f.retries, f.failovers, f.degraded_ops,
            f.remapped_blocks, f.shed_requests, f.n_op_errors) \
        == (0, 0, 0, 0, 0, 0, 0)


def test_dead_chip_failover_bit_identical_to_healthy():
    """Chip 0 dead from t=0 with replicas=2: every read of its pages
    fails over to a replica, and the answers are bit-identical to the
    healthy replay — faults surface as latency and counters, never as
    wrong data."""
    wl = generate(300, n_key_pages=8, read_ratio=0.6, alpha=0.9, seed=7,
                  scan_ratio=0.05)
    kw = dict(burst=16, fused=True, seed=7)
    healthy = replay(wl, _mk_replicated(wl), RunConfig.event_serial(
        faults=FaultSchedule.healthy(seed=7), **kw))
    dead = replay(wl, _mk_replicated(wl), RunConfig.event_serial(
        faults=FaultSchedule.dead_chip(chip=0, seed=7), **kw))
    np.testing.assert_array_equal(healthy.read_values, dead.read_values)
    np.testing.assert_array_equal(healthy.read_hits, dead.read_hits)
    if healthy.scan_counts is not None:
        np.testing.assert_array_equal(healthy.scan_counts,
                                      dead.scan_counts)
    assert dead.faults.failovers > 0       # the replica path actually ran
    assert dead.faults.degraded_ops > 0
    assert dead.faults.n_op_errors == 0    # zero ops lost, zero wrong


def test_dead_chip_without_replicas_fails_typed():
    """replicas=1 and a dead chip: reads of its pages have nowhere to
    fail over — they must surface as typed per-op errors (op_errors),
    never as fabricated values, and every other op still completes."""
    wl = generate(300, n_key_pages=8, read_ratio=0.6, alpha=0.9, seed=7)
    r = replay(wl, _mk_replicated(wl, replicas=1), RunConfig.event_serial(
        faults=FaultSchedule.dead_chip(chip=0, seed=7), burst=16))
    f = r.faults
    assert f.n_op_errors > 0
    assert f.failovers == 0                # no replicas to fail over to
    # errored reads report miss/zero, completed ones match the healthy run
    healthy = replay(wl, _mk_replicated(wl, replicas=1),
                     RunConfig.event_serial(burst=16))
    ok = ~f.op_errors
    np.testing.assert_array_equal(r.read_values[ok],
                                  healthy.read_values[ok])
    assert not r.read_hits[f.op_errors].any()
    assert not r.read_values[f.op_errors].any()


def test_chaos_determinism_property():
    """Same seed + same fault schedule => identical RunReport: values,
    per-op errors, every fault counter, the event trace and the latency
    tail, for every schedule shape."""
    hypothesis = pytest.importorskip("hypothesis")
    given = hypothesis.given
    st = hypothesis.strategies

    wl = generate(160, n_key_pages=8, read_ratio=0.6, alpha=0.9, seed=4)

    def run_once(sched, deadline):
        return replay(wl, _mk_replicated(wl), RunConfig.chaos(
            sched, deadline_ns=deadline, max_retries=3,
            backoff_base_ns=100_000.0, concurrency=4, burst=16, seed=5,
            record_trace=True))

    @given(kind=st.sampled_from(["healthy", "transient_stall",
                                 "dying_die", "dead_chip"]),
           fault_seed=st.integers(min_value=0, max_value=5),
           deadline=st.sampled_from([400_000.0, 800_000.0]))
    @hypothesis.settings(max_examples=8, deadline=None)
    def prop(kind, fault_seed, deadline):
        mk_sched = {
            "healthy": lambda: FaultSchedule.healthy(seed=fault_seed),
            "transient_stall": lambda: FaultSchedule.transient_stall(
                die=0, t_start_ms=0.05, dur_ms=1.0, seed=fault_seed),
            "dying_die": lambda: FaultSchedule.dying_die(
                die=1, t_fail_ms=0.5, program_fail_prob=0.05,
                seed=fault_seed),
            "dead_chip": lambda: FaultSchedule.dead_chip(
                chip=0, seed=fault_seed),
        }[kind]
        a = run_once(mk_sched(), deadline)
        b = run_once(mk_sched(), deadline)
        np.testing.assert_array_equal(a.read_values, b.read_values)
        np.testing.assert_array_equal(a.faults.op_errors,
                                      b.faults.op_errors)
        for field in ("timeouts", "retries", "backoff_waits",
                      "hedges_won", "failovers", "remapped_blocks",
                      "degraded_ops", "shed_requests", "replica_programs",
                      "program_failures", "n_op_errors"):
            assert getattr(a.faults, field) == getattr(b.faults, field), \
                field
        assert a.counters == b.counters
        assert a.latency.read_p99_ns == b.latency.read_p99_ns
        assert a.trace == b.trace and len(a.trace) > 0

    prop()


def test_chaos_determinism_seeded_fallback():
    """No-hypothesis fallback: one deterministic pass per schedule shape
    so the same-seed contract is exercised even where hypothesis is
    absent."""
    wl = generate(160, n_key_pages=8, read_ratio=0.6, alpha=0.9, seed=4)
    for sched in (FaultSchedule.transient_stall(die=0, t_start_ms=0.05,
                                                dur_ms=1.0, seed=3),
                  FaultSchedule.dead_chip(chip=0, seed=3)):
        cfg = RunConfig.chaos(sched, deadline_ns=400_000.0, max_retries=3,
                              backoff_base_ns=100_000.0, concurrency=4,
                              burst=16, seed=5)
        a = replay(wl, _mk_replicated(wl), cfg)
        b = replay(wl, _mk_replicated(wl), cfg)
        np.testing.assert_array_equal(a.read_values, b.read_values)
        np.testing.assert_array_equal(a.faults.op_errors,
                                      b.faults.op_errors)
        assert a.counters == b.counters
        assert a.latency.read_p99_ns == b.latency.read_p99_ns
