"""Training loop, checkpoint/restart, elastic resharding, fault tolerance."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.launch.train import train
from repro.models.model import init_model
from repro.train.checkpoint import (latest_step, load_checkpoint,
                                    save_checkpoint)
from repro.train.data import DataConfig, batch_at_step
from repro.train.ft import FailureInjector, StragglerWatchdog
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def test_data_pipeline_deterministic_and_stateless():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=3)
    b1 = batch_at_step(cfg, 7)
    b2 = batch_at_step(cfg, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = batch_at_step(cfg, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert (np.asarray(b1["labels"])[:, -1] == -1).all()


def test_training_reduces_loss_end_to_end():
    run = train("olmo-1b", steps=30, batch=8, seq_len=32, lr=3e-3,
                verbose=False)
    assert run.steps_run == 30
    early = np.mean(run.losses[:5])
    late = np.mean(run.losses[-5:])
    assert late < early - 0.3, (early, late)   # ~0.8 nats over 30 steps


def test_checkpoint_roundtrip(tmp_path):
    cfg = dataclasses.replace(reduced_config(ARCHS["qwen3-4b"]),
                              dtype="float32")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params, AdamWConfig())
    save_checkpoint(tmp_path / "step_5", 5, params, opt, config_name="t")
    step, p2, o2 = load_checkpoint(tmp_path / "step_5", params, opt)
    assert step == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert latest_step(tmp_path).name == "step_5"


def test_crash_restart_bitwise_resume(tmp_path):
    """Uninterrupted run == crash-at-step-12 + restart run, bitwise."""
    kw = dict(steps=20, batch=4, seq_len=16, lr=1e-3, verbose=False,
              ckpt_every=10)
    full = train("olmo-1b", ckpt_root=tmp_path / "a", **kw)

    with pytest.raises(RuntimeError, match="injected failure"):
        train("olmo-1b", ckpt_root=tmp_path / "b", crash_at=12, **kw)
    resumed = train("olmo-1b", ckpt_root=tmp_path / "b", **kw)
    assert resumed.resumed_from == 10
    # steps 10..19 of both runs must agree exactly
    np.testing.assert_array_equal(np.asarray(full.losses[10:]),
                                  np.asarray(resumed.losses))


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint saved from one mesh loads onto another (1x1 -> 1-dev
    degenerate here; the sharding trees differ in axis names, which is the
    code path elasticity exercises)."""
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import shardings_for_tree
    cfg = reduced_config(ARCHS["granite-3-8b"])
    params, axes = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params, AdamWConfig())
    save_checkpoint(tmp_path / "step_1", 1, params, opt)
    mesh2 = make_host_mesh()
    p_sh = shardings_for_tree(params, axes, mesh2, fsdp=False)
    step, p2, _ = load_checkpoint(tmp_path / "step_1", params, opt,
                                  shardings=p_sh)
    leaf = jax.tree.leaves(p2)[0]
    assert leaf.sharding.mesh.axis_names == ("data", "model")


def test_straggler_watchdog_flags_slow_step():
    w = StragglerWatchdog(threshold=3.0, warmup_steps=3)
    for s in range(6):
        w.start_step(s)
        time.sleep(0.005)
        assert w.end_step() is None
    w.start_step(6)
    time.sleep(0.06)
    ev = w.end_step()
    assert ev is not None and ev.slowdown > 3


def test_failure_injector_fires_once():
    inj = FailureInjector(crash_at_step=3)
    inj.maybe_crash(2)
    with pytest.raises(RuntimeError):
        inj.maybe_crash(3)
    inj.maybe_crash(3)          # second pass: already fired


def test_microbatch_accumulation_matches_full_batch():
    cfg = dataclasses.replace(reduced_config(ARCHS["olmo-1b"]),
                              dtype="float32", remat="none")
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8,
                      seed=0)
    batch = batch_at_step(data, 0)
    s1 = make_train_step(cfg, opt_cfg, microbatches=1)
    s2 = make_train_step(cfg, opt_cfg, microbatches=4)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
