# analysis: pretend-path=src/repro/index/fixture_consumer.py
"""SIM005 true positives: match results consumed with the error channel
ignored — an uncorrectable page's all-zero bitmap reads as a miss."""
import numpy as np


def silent_bitmap_consumer(backend, cmd):
    resp = backend.search(cmd)
    return np.nonzero(resp.bitmap_words)[0]     # no verdict check anywhere


def silent_count_and_slot(tickets):
    total = 0
    slots = []
    for t in tickets:
        r = t.result()
        total += r.match_count                  # error channel ignored
        slots.append(r.value_slot)
    return total, slots
