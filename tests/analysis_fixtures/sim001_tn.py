# analysis: pretend-path=src/repro/fixtures/sim001_tn.py
"""SIM001 true negatives: flushed bursts and deferred-result scopes."""


def flushed_burst(backend, cmds):
    tickets = [backend.submit_search(c) for c in cmds]
    backend.flush()
    return [t.result() for t in tickets]


def submit_only(backend, cmd):
    # Returning the ticket hands resolution to the caller — not a drop.
    return backend.submit_search(cmd)


def deferred_result(backend, cmd):
    t = backend.submit_search(cmd)

    def resolve():
        # nested def is its own scope; cross-scope flow is the launch
        # audit's job, not the AST rule's
        return t.result()

    backend.flush()
    return resolve
