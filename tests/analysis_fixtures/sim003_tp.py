# analysis: pretend-path=src/repro/backend/fixture_flush.py
"""SIM003 true positives: host syncs on launch outputs inside the flush."""
import numpy as np


def sim_search(lo, hi, q, m):
    return lo


def _flush_searches(lo, hi, q, m):
    out = sim_search(lo, hi, q, m)
    host = np.asarray(out)          # device->host copy at flush time
    total = int(out[0])             # blocking scalar sync at flush time
    out.block_until_ready()         # explicit barrier in the hot path
    return host, total
