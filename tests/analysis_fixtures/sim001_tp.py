# analysis: pretend-path=src/repro/fixtures/sim001_tp.py
"""SIM001 true positives: dropped tickets and un-flushed .result()."""


def drops_ticket(backend, cmd):
    # The ticket is discarded: nothing can ever verify it resolved.
    backend.submit_search(cmd)


def result_without_flush(backend, cmd):
    t = backend.submit_search(cmd)
    return t.result()      # no flush between submit and result


def mixed_burst(backend, cmds):
    tickets = [backend.submit_gather(c) for c in cmds]
    return [t.result() for t in tickets]   # flush never called
