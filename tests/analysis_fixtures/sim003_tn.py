# analysis: pretend-path=src/repro/backend/fixture_flush.py
"""SIM003 true negatives: host tail deferred, host values freely cast."""
import numpy as np


def sim_search(lo, hi, q, m):
    return lo


def _flush_searches(lo, hi, q, m, cmds):
    out = sim_search(lo, hi, q, m)
    n = int(len(cmds))              # host value: int() here is fine

    def tail(out=out):
        # nested def = deferred tail, runs after the flush returns
        return np.asarray(out)[:n]

    return tail


def resolve_burst(out):
    # not a hot-scope name: the drain path MAY sync
    return np.asarray(out)
