# analysis: pretend-path=src/repro/fixtures/sim007_tp.py
"""SIM007 true positives: physical dimensions crossing suffix boundaries.

Includes the interprocedural case the per-function SIM001–006 generation
could never see: a helper's *return* dimension flowing into a parameter
that declares a different one, two calls away.
"""


def adds_time_to_energy(lat_ns, cost_pj):
    return lat_ns + cost_pj                 # mix:ns+pj


def mislabels_assignment(t_ns):
    energy_pj = t_ns                        # mis-assign:energy_pj
    return energy_pj


def mislabeled_keyword(charge, dt_ns):
    return charge(cost_pj=dt_ns)            # mis-call:charge.cost_pj


def compares_bytes_to_time(n_bytes, dt_ns):
    return n_bytes < dt_ns                  # mix:bytes+ns (comparison)


def returns_wrong_dim_ns(cost_pj):
    return cost_pj                          # mis-return:pj


def total_latency_ns(a_ns, b_ns):
    return a_ns + b_ns


def charge_energy(energy_pj):
    return energy_pj * 1.0


def cross_function_leak(a_ns, b_ns):
    # Interprocedural: the helper's summarized return dimension (ns) lands
    # in a pj-suffixed positional parameter — no single-function view of
    # either callee shows the mismatch.
    return charge_energy(total_latency_ns(a_ns, b_ns))
