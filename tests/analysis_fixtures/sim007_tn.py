# analysis: pretend-path=src/repro/fixtures/sim007_tn.py
"""SIM007 true negatives: the unit algebra the repo legitimately uses —
conversions by multiplication, rates by division, same-dimension sums,
dimensionless intermediates — must never false-positive."""

MS_NS = 1_000_000.0


def window_ns(t_start_ms):
    return t_start_ms * MS_NS               # conversion: multiply is unknown


def bandwidth(n_bytes, dt_ns):
    return n_bytes / dt_ns                  # rate: division clears the dim


def add_same_dimension(a_ns, b_ns):
    total_ns = a_ns + b_ns
    return total_ns + 1.0                   # literals are dimensionless


def dimensionless_intermediate(a_ns, scale):
    x = a_ns * scale
    return x + 7                            # unknown + unknown: clean


def accumulate(energy_pj, step_pj, n):
    for _ in range(n):
        energy_pj += step_pj                # augmented same-dim sum
    return energy_pj


def helper_latency_ns(a_ns, b_ns):
    return max(a_ns, b_ns)                  # passthrough keeps the dim


def charge_time(total_ns):
    return total_ns


def cross_function_same_dim(a_ns, b_ns):
    # interprocedural TN: summarized ns return into an ns parameter
    return charge_time(helper_latency_ns(a_ns, b_ns))
