# analysis: pretend-path=src/repro/backend/fixture_stats.py
"""SIM004 true positives: BackendStats mutated outside the helpers."""


class FixtureBackend:
    def record_hit(self):
        self.stats.result_bytes += 64      # not an accounting helper

    def reset_counters(self):
        self.stats = object()              # wholesale replacement
