# analysis: pretend-path=src/repro/backend/fixture_stats.py
"""SIM004 true negatives: counters move only in accounting helpers."""


class FixtureBackend:
    def flush(self):
        self.stats.flushes += 1

    def _flush_searches(self, searches):
        self.stats.kernel_launches += 1

        def tail():
            self.stats.result_bytes += 64
        return tail

    def submit_program(self, page, entries):
        self.stats.programs_coalesced += 1
