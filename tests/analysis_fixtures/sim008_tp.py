# analysis: pretend-path=src/repro/fixtures/sim008_tp.py
"""SIM008 true positives: RNG constructions whose entropy never traces to
a declared seed — including the interprocedural case where the entropy is
a parameter and a call site passes an unseeded value."""
import numpy as np


def no_entropy_at_all():
    return np.random.default_rng()          # unseeded-rng


def os_entropy_laundered():
    import time
    noise = time.time_ns()                  # not a seed: wall-clock entropy
    return np.random.default_rng(noise)     # untraced-rng


def _fixture_rng_from_knob(knob):
    # provenance depends on every caller: flagged via the call sites below
    return np.random.default_rng(knob)      # untraced-rng:knob


def passes_wallclock(clock):
    return _fixture_rng_from_knob(clock.tick())
