# analysis: pretend-path=src/repro/frontend/fixture_retry.py
"""SIM006 true positives: unbounded retries, silent swallowing, unseeded
randomness — each the exact failure mode the device-fault tier forbids."""
import numpy as np


def retries_forever(backend, cmd):
    while True:                             # no break: hangs on outage
        try:
            return backend.search(cmd)
        except IOError:
            continue


def swallows_silently(ticket):
    try:
        return ticket.result()
    except Exception:
        pass                                # error channel vanishes


def swallows_with_ellipsis(ticket, fallback):
    try:
        return ticket.result()
    except (ValueError, IOError):
        ...                                 # same vanishing, spelled ...
    return fallback


def unseeded_jitter(base_ns):
    rng = np.random.default_rng()           # OS entropy: nondeterministic
    return base_ns * rng.random()
