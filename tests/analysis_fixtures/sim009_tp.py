# analysis: pretend-path=src/repro/fixtures/sim009_tp.py
"""SIM009 true positives: multi-command bursts resolved through the
Ticket.result() auto-flush instead of an explicit flush() — including the
interprocedural case where the submits hide inside a helper, which no
per-function rule could catch."""


def looped_implicit_burst(backend, cmds):
    tickets = [backend.submit_search(c) for c in cmds]
    return [t.result() for t in tickets]    # result-no-flush:submit_search


def two_pending_at_result(backend, a, b):
    t1 = backend.submit_search(a)
    t2 = backend.submit_gather(b)
    return t1.result(), t2.result()         # two commands pending


def _stage_probe(backend, cmd):
    # returns with its ticket still pending — the caller must flush
    return backend.submit_search(cmd)


def helper_hidden_burst(backend, a, b):
    t1 = _stage_probe(backend, a)
    t2 = _stage_probe(backend, b)
    # interprocedural: the pending tickets were created two frames down,
    # so the old syntactic SIM001 saw no submit_* here at all
    return t1.result(), t2.result()         # result-no-flush:_stage_probe
