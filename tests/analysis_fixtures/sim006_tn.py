# analysis: pretend-path=src/repro/frontend/fixture_retry_ok.py
"""SIM006 true negatives: bounded retries, typed failures, seeded rngs —
the disciplines the device-fault tier actually uses."""
import numpy as np

MAX_ATTEMPTS = 8


class TypedError(RuntimeError):
    pass


def bounded_retry(backend, cmd):
    for attempt in range(MAX_ATTEMPTS):     # bounded: always terminates
        try:
            return backend.search(cmd)
        except IOError:
            continue
    raise TypedError("retries exhausted")   # typed, not swallowed


def while_true_with_break(backend, cmd):
    while True:
        try:
            resp = backend.search(cmd)
        except IOError:
            raise TypedError("search failed")
        break                               # bounded by the break
    return resp


def records_the_outcome(ticket, stats):
    try:
        return ticket.result()
    except IOError:
        stats.failures += 1                 # outcome recorded, not lost
        return None


def seeded_jitter(seed, qi, attempt, base_ns):
    rng = np.random.default_rng([seed, 0xB0FF, qi, attempt])
    return base_ns * rng.random()           # entropy-list idiom


def poll_loop_without_try(queue):
    while True:                             # not a retry loop: no try
        item = queue.get()
        if item is None:
            break
    return item
