# analysis: pretend-path=src/repro/core/engine.py
"""SIM002 true positive: page mutation without an observer notify."""


class FixtureChip:
    def __init__(self, pages):
        self.pages = pages       # __init__ is exempt by design

    def silent_rewrite(self, local, image):
        self.pages[local] = image      # no _notify -> stale arena rows
