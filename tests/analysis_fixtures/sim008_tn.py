# analysis: pretend-path=src/repro/fixtures/sim008_tn.py
"""SIM008 true negatives: every seeding idiom the repo actually uses —
direct seeds, entropy lists mixing a seed with op indices, derived seeds,
seeded-returning helpers, and interprocedurally-proven parameters."""
import numpy as np


def direct_seed(seed):
    return np.random.default_rng(seed)


def entropy_list_idiom(seed, qi, attempt):
    # one seeded component makes the mix deterministic given the seed
    return np.random.default_rng([seed, 0xB0FF, qi, attempt])


def derived_seed(config):
    return np.random.default_rng(config.seed ^ 0xD1CE)


def literal_seed():
    return np.random.default_rng(1234)


def _derive_entropy(base):
    return 0xFEED + base                    # literal component: seeded


def via_seeded_helper(base):
    # the helper's returns-seeded summary proves this clean
    return np.random.default_rng(_derive_entropy(base))


def _fixture_rng_from_key(key):
    # the parameter is proven seeded at every call site below
    return np.random.default_rng(key)


def all_sites_seeded(schedule, qi):
    return _fixture_rng_from_key([schedule.seed, qi])
