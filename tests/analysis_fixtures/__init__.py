# Known-good / known-bad fixture modules for the repro.analysis contract
# linter (tests/test_analysis_contracts.py).  Each *_tp.py module carries
# deliberate violations; each *_tn.py is the compliant twin.  The
# `# analysis: pretend-path=` pragma re-homes a fixture so path-scoped
# rules (SIM002-004) treat it as an in-scope file.  These modules are
# PARSED, never imported by product code.
