# analysis: pretend-path=src/repro/fixtures/sim009_tn.py
"""SIM009 true negatives: the documented immediate mode (one straight-line
submit + result — what the old syntactic SIM001 falsely flagged on the
MatchBackend eager wrappers) and bursts resolved by explicit or
interprocedurally-summarized flushes."""


def eager_wrapper(backend, cmd):
    # single pending ticket: Ticket.result()'s auto-flush IS the
    # documented immediate mode, not an implicit multi-command burst
    return backend.submit_search(cmd).result()


def flushed_burst(backend, cmds):
    tickets = [backend.submit_search(c) for c in cmds]
    backend.flush()
    return [t.result() for t in tickets]


def _stage_and_flush(backend, cmds):
    tickets = [backend.submit_gather(c) for c in cmds]
    backend.flush()
    return tickets


def helper_flushed_burst(backend, cmds):
    # the helper's may-flush summary proves the burst resolved
    tickets = _stage_and_flush(backend, cmds)
    return [t.result() for t in tickets]


def submit_only(backend, cmd):
    # handing the ticket to the caller is not a violation here
    return backend.submit_search(cmd)
