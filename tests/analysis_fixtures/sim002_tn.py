# analysis: pretend-path=src/repro/core/engine.py
"""SIM002 true negative: every page mutation notifies the observers."""


class FixtureChip:
    def __init__(self, pages):
        self.pages = pages

    def _notify(self, local):
        pass

    def notified_rewrite(self, local, image):
        self.pages[local] = image
        self._notify(local)

    def read_only(self, local):
        return self.pages[local]       # loads never need a notify
