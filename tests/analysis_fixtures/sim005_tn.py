# analysis: pretend-path=src/repro/index/fixture_consumer_ok.py
"""SIM005 true negatives: every consumption acknowledges the channel, and
the exempt layers (backend/, reliability/, ...) are out of scope anyway."""
import numpy as np

from repro.reliability import UncorrectableReadError, require_clean


def wrapped_consumer(backend, cmd):
    resp = require_clean(backend.search(cmd))
    return np.nonzero(resp.bitmap_words)[0]


def verdict_inspector(tickets):
    out = []
    for t in tickets:
        r = t.result()
        if r.open_verdict != "clean":
            continue
        out.append(r.match_count)
    return out


def error_handler(ticket):
    try:
        return ticket.result().value_slot
    except UncorrectableReadError:
        return None


def no_consumption(backend, cmd):
    # builds a response-shaped thing but never loads a result attribute
    return backend.search(cmd)
