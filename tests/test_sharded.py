"""ShardedSsdBackend: addressing round-trips, cross-geometry bit-parity,
one-dispatch-per-burst, index wiring and timeline-coupled accounting.

The sharded backend owns channels x dies chips behind the MatchBackend
contract; stored-image randomization cancels between program and search,
so responses must be bit-identical across EVERY geometry — 1x1, 4x4 —
and against the scalar/batched single-arena references.
"""
import numpy as np
import pytest

from repro.backend import (BatchedKernelBackend, ScalarBackend,
                           ShardedSsdBackend, make_backend)
from repro.backend.sharded import compose, decompose
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.flash.timeline import BurstTimeline, ChipBurst
from repro.index.btree import SimBTree
from repro.index.hashindex import SimHashIndex
from repro.frontend import RunConfig, replay
from repro.workload.ycsb import generate

N_PAGES = 16
ENTRIES_PER_PAGE = 250


# ------------------------------------------------------------- addressing
def test_decompose_compose_roundtrip_sweep():
    """Any page set round-trips the (chip, local) decomposition."""
    for n_chips in (1, 2, 3, 5, 8, 16):
        for addr in range(0, 2000, 7):
            chip, local = decompose(addr, n_chips)
            assert 0 <= chip < n_chips
            assert compose(chip, local, n_chips) == addr
        # ...and every (chip, local) pair maps to a distinct address.
        seen = {compose(c, p, n_chips)
                for c in range(n_chips) for p in range(64)}
        assert len(seen) == n_chips * 64


def test_decompose_matches_simchiparray_route():
    """The sharded namespace and the chip array stripe identically, so
    stored images (which depend on local address + per-chip seed) agree."""
    arr = SimChipArray(n_chips=6, pages_per_chip=8, device_seed=3)
    for addr in range(40):
        chip, local = decompose(addr, 6)
        routed_chip, routed_local = arr.route(addr)
        assert routed_chip is arr.chips[chip]
        assert routed_local == local


def test_decompose_roundtrip_property():
    hypothesis = pytest.importorskip("hypothesis")
    given = hypothesis.given
    st = hypothesis.strategies

    @hypothesis.settings(max_examples=200, deadline=None)
    @given(st.integers(0, 2**40), st.integers(1, 1024))
    def roundtrip(addr, n_chips):
        chip, local = decompose(addr, n_chips)
        assert 0 <= chip < n_chips and local >= 0
        assert compose(chip, local, n_chips) == addr

    roundtrip()


def test_geometry_validation():
    arr = SimChipArray(n_chips=6, pages_per_chip=8)
    with pytest.raises(ValueError):
        ShardedSsdBackend(arr, channels=4, dies_per_channel=4)
    be = ShardedSsdBackend(arr, channels=3, dies_per_channel=2)
    assert (be.channels, be.dies_per_channel, be.n_chips) == (3, 2, 6)
    with pytest.raises(ValueError):
        ShardedSsdBackend(SimChipArray(n_chips=4, pages_per_chip=8),
                          timeline=BurstTimeline.for_chips(16))


# ----------------------------------------------------------------- parity
def _programmed(page_keys, make):
    be = make()
    for p, keys in enumerate(page_keys):
        be.program_entries(p, keys)
    return be


@pytest.fixture(scope="module")
def backends():
    """scalar / batched (shared 16-chip array layout) + sharded 1x1 and
    4x4 — four backends over identically-keyed page sets."""
    rng = np.random.default_rng(7)
    page_keys = [rng.integers(1, 2**62, ENTRIES_PER_PAGE, dtype=np.uint64)
                 for _ in range(N_PAGES)]
    mk = {
        "scalar": lambda: ScalarBackend(
            SimChipArray(n_chips=16, pages_per_chip=8, device_seed=31)),
        "batched": lambda: BatchedKernelBackend(
            SimChipArray(n_chips=16, pages_per_chip=8, device_seed=31)),
        "sharded1x1": lambda: ShardedSsdBackend.from_geometry(
            channels=1, pages_per_chip=N_PAGES, device_seed=31),
        "sharded4x4": lambda: ShardedSsdBackend.from_geometry(
            channels=4, dies_per_channel=4, pages_per_chip=8,
            device_seed=31),
    }
    return {k: _programmed(page_keys, m) for k, m in mk.items()}, page_keys


def test_search_bitmaps_bit_identical_across_geometries(backends):
    bes, page_keys = backends
    rng = np.random.default_rng(1)
    cmds = []
    for _ in range(40):
        p = int(rng.integers(0, N_PAGES))
        if rng.random() < 0.5:                      # planted hit
            q, mask = int(page_keys[p][rng.integers(
                0, ENTRIES_PER_PAGE)]), 0xFFFFFFFFFFFFFFFF
        else:                                       # masked / miss
            q = int(rng.integers(1, 2**62))
            mask = int(rng.integers(0, 2**64, dtype=np.uint64))
        cmds.append(Command.search(p, q, mask))
    cmds.append(Command.search(0, 0, 0))            # §V-D match-all

    results = {}
    for name, be in bes.items():
        ts = [be.submit_search(c) for c in cmds]
        before = be.stats.kernel_launches
        be.flush()
        if isinstance(be, ShardedSsdBackend):       # one dispatch per burst
            assert be.stats.kernel_launches == before + 1
        results[name] = [t.result() for t in ts]
    ref = results["scalar"]
    for got in results.values():
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a.bitmap_words, b.bitmap_words)
            assert a.match_count == b.match_count


def test_gathers_bit_identical_across_geometries(backends):
    bes, page_keys = backends
    rng = np.random.default_rng(2)
    cmds = [Command.gather(p, int(rng.integers(0, 2**64, dtype=np.uint64)))
            for p in range(N_PAGES)]
    cmds += [Command.gather(0, 0), Command.gather(1, 0xFFFFFFFFFFFFFFFF)]
    results = {name: [t.result() for t in
                      [be.submit_gather(c) for c in cmds]]
               for name, be in bes.items()}
    ref = results["scalar"]
    for got in results.values():
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a.chunks, b.chunks)
            np.testing.assert_array_equal(a.chunk_ids, b.chunk_ids)
            np.testing.assert_array_equal(a.parity_ok, b.parity_ok)


def test_lookups_bit_identical_across_geometries(backends):
    """Fused lookups whose key and value pages live on different chips."""
    bes, page_keys = backends
    rng = np.random.default_rng(4)
    cmds = []
    for _ in range(20):
        kp = int(rng.integers(0, N_PAGES // 2))
        vp = kp + N_PAGES // 2                      # different chip in 4x4
        q = int(page_keys[kp][rng.integers(0, ENTRIES_PER_PAGE)]) \
            if rng.random() < 0.7 else int(rng.integers(2**62, 2**63))
        cmds.append(Command.lookup(kp, vp, q))
    results = {}
    for name, be in bes.items():
        ts = [be.submit_lookup(c) for c in cmds]
        before = be.stats.kernel_launches
        be.flush()
        if isinstance(be, ShardedSsdBackend):
            assert be.stats.kernel_launches == before + 1
        results[name] = [t.result() for t in ts]
    ref = results["scalar"]
    misses = 0
    for got in results.values():
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a.search.bitmap_words,
                                          b.search.bitmap_words)
            assert a.value_slot == b.value_slot
            assert a.value == b.value
            assert a.parity_ok == b.parity_ok
            misses += a.value_slot is None
    assert misses and misses < len(cmds) * len(results)


def test_reprogram_invalidates_one_arena_row(backends):
    bes, page_keys = backends
    be = bes["sharded4x4"]
    be.search(Command.search(5, int(page_keys[5][0])))      # warm page 5
    warm = be.stats.staged_bytes
    new_keys = page_keys[5][::-1].copy()
    be.program_entries(5, new_keys)
    resp = be.search(Command.search(5, int(new_keys[3])))
    assert resp.match_count >= 1
    assert be.stats.staged_bytes - warm == 4096             # one dirty row


# ---------------------------------------------------------- index wiring
def test_btree_on_sharded_backend():
    rng = np.random.default_rng(5)
    keys = (rng.choice(10**9, size=900, replace=False) + 1).astype(np.uint64)
    values = keys * np.uint64(13)
    bt = SimBTree(ShardedSsdBackend.from_geometry(
        channels=4, dies_per_channel=2, pages_per_chip=32))
    bt.bulk_load(keys, values)
    # §V-A pairing: consecutive (key, value) pages stripe to distinct chips
    for leaf in bt.leaves:
        assert decompose(leaf.key_page, 8)[0] != \
            decompose(leaf.value_page, 8)[0]
    probes = [int(k) for k in keys[::83]] + [int(keys[0]) + 1]
    want = [int(k) * 13 if k in set(keys.tolist()) else None for k in probes]
    assert bt.lookup_batch(probes) == want
    lo, hi = int(np.percentile(keys, 40)), int(np.percentile(keys, 45))
    expect = sorted((int(k), int(k) * 13) for k in keys if lo <= int(k) < hi)
    assert sorted(bt.range_query(lo, hi)) == expect


def test_secondary_index_on_sharded_backend():
    """A full-table predicate scan: every chip matches its shard of the
    table inside one stacked launch, same rows as the scalar reference."""
    from repro.core.bitweaving import Column, RowCodec
    from repro.index.secondary import SimSecondaryIndex
    codec = RowCodec((Column("uid", 40), Column("age", 7),
                      Column("gender", 1)))
    rng = np.random.default_rng(8)
    rows = {"uid": rng.integers(0, 2**40, 1500, dtype=np.uint64),
            "age": rng.integers(0, 100, 1500, dtype=np.uint64),
            "gender": rng.integers(0, 2, 1500, dtype=np.uint64)}
    got = {}
    for name, make in (("scalar", lambda: make_backend(
            "scalar", SimChipArray(n_chips=8, pages_per_chip=8))),
            ("sharded", lambda: ShardedSsdBackend.from_geometry(
                channels=4, dies_per_channel=2, pages_per_chip=8))):
        idx = SimSecondaryIndex(make(), codec)
        idx.load_rows(rows)
        eq = idx.select_equals("gender", 1)
        rg = idx.select_range("age", 30, 40)
        got[name] = (np.sort(eq), np.sort(rg))
        if name == "sharded":
            assert idx.backend.stats.kernel_launches > 0
    np.testing.assert_array_equal(got["scalar"][0], got["sharded"][0])
    np.testing.assert_array_equal(got["scalar"][1], got["sharded"][1])
    want_age = np.sort(codec.encode_rows(rows)[
        (rows["age"] >= 30) & (rows["age"] < 40)])
    np.testing.assert_array_equal(got["sharded"][1], want_age)


def test_hash_index_on_sharded_backend():
    rng = np.random.default_rng(6)
    keys = (rng.choice(10**9, size=500, replace=False) + 1).astype(np.uint64)
    results = []
    for make in (lambda: make_backend(
            "scalar", SimChipArray(n_chips=8, pages_per_chip=512)),
            lambda: ShardedSsdBackend.from_geometry(
                channels=4, dies_per_channel=2, pages_per_chip=512)):
        h = SimHashIndex(make())
        for k in keys:
            h.insert(int(k), int(k) * 7)
        results.append(h.lookup_batch([int(k) for k in keys[::19]]
                                      + [10**15 + 3]))
    assert results[0] == results[1]
    assert results[0][-1] is None


# -------------------------------------------------------------- workloads
@pytest.fixture(scope="module")
def ycsb_replays():
    wl = generate(240, n_key_pages=6, read_ratio=0.8, alpha=0.5, seed=11)
    outs = {}
    for name, make in {
        "scalar": lambda: make_backend("scalar", SimChipArray(
            n_chips=4, pages_per_chip=16, device_seed=3)),
        "batched": lambda: make_backend("batched", SimChipArray(
            n_chips=4, pages_per_chip=16, device_seed=3)),
        "sharded1x1": lambda: ShardedSsdBackend.from_geometry(
            channels=1, pages_per_chip=64, device_seed=3, timeline=True),
        "sharded4x4": lambda: ShardedSsdBackend.from_geometry(
            channels=4, dies_per_channel=4, pages_per_chip=8,
            device_seed=3, timeline=True),
    }.items():
        for fused in (False, True):
            outs[(name, fused)] = replay(wl, make(),
                                         RunConfig(burst=32, fused=fused))
    return wl, outs


def test_ycsb_replay_bit_identical_across_geometries(ycsb_replays):
    """4-channel x 4-die replay == scalar reference, split and fused."""
    wl, outs = ycsb_replays
    ref = outs[("scalar", False)]
    assert ref.read_hits[wl.ops == 0].all()
    for r in outs.values():
        np.testing.assert_array_equal(ref.read_values, r.read_values)
        np.testing.assert_array_equal(ref.read_hits, r.read_hits)


def test_ycsb_fused_burst_is_one_dispatch(ycsb_replays):
    _, outs = ycsb_replays
    fused = outs[("sharded4x4", True)]
    assert fused.kernel_launches == fused.flushes    # 1 launch per burst
    split = outs[("sharded4x4", False)]
    assert split.kernel_launches == 2 * fused.kernel_launches


# --------------------------------------------------------------- timeline
def test_timeline_couples_functional_run(ycsb_replays):
    _, outs = ycsb_replays
    r = outs[("sharded4x4", True)]
    assert r.burst_latencies_ns is not None
    assert len(r.burst_latencies_ns) == r.flushes
    assert (r.burst_latencies_ns > 0).all()
    assert r.write_latencies_ns is not None and len(r.write_latencies_ns)
    assert r.sim_makespan_ns > 0 and r.sim_energy_pj > 0
    assert np.percentile(r.burst_latencies_ns, 99) >= \
        np.percentile(r.burst_latencies_ns, 50)


def test_timeline_die_channel_parallelism(ycsb_replays):
    """The same op stream finishes faster on 16 dies than on 1 — the
    channel/die overlap the paper's speedups come from (§VI-A)."""
    _, outs = ycsb_replays
    one = outs[("sharded1x1", True)]
    many = outs[("sharded4x4", True)]
    assert many.sim_makespan_ns < one.sim_makespan_ns
    assert np.median(many.burst_latencies_ns) < \
        np.median(one.burst_latencies_ns)


def test_timeline_charges_bus_writeback_only_for_dirty_planes():
    """Cold first-touch arena staging is a TPU artifact, not SSD channel
    traffic: a read-only replay must accrue zero storage-mode bus bytes,
    while a reprogram charges exactly one page's write-back crossing."""
    be = ShardedSsdBackend.from_geometry(
        channels=2, dies_per_channel=2, pages_per_chip=8, timeline=True)
    rng = np.random.default_rng(3)
    keys = [rng.integers(1, 2**62, 50, dtype=np.uint64) for _ in range(8)]
    for p, k in enumerate(keys):
        be.program_entries(p, k)
    be.timeline.reset()
    bus0 = be.timeline.sim.stats.internal_bytes
    for p in range(8):                      # cold first-touch searches
        be.search(Command.search(p, int(keys[p][0])))
    # all bus traffic so far is match-mode (opens + bitmaps): 320 B per
    # search, nowhere near the 4 KiB/page a restage charge would add
    match_only = be.timeline.sim.stats.internal_bytes - bus0
    assert match_only == 8 * (256 + 64)
    lat_before = list(be.timeline.burst_latencies)
    be.program_entries(3, keys[3][::-1].copy())         # dirty one plane
    be.search(Command.search(3, int(keys[3][-1])))
    assert len(be.timeline.burst_latencies) == len(lat_before) + 1
    # the dirty burst carries the 4 KiB storage-mode write-back crossing
    assert be.timeline.burst_latencies[-1] > np.median(lat_before)


def test_timeline_resource_accounting():
    """Flush reports drive SSDSim's timelines: senses/matches/bytes land
    on the right counters and chips on one channel serialize their bus."""
    tl = BurstTimeline.for_chips(4)
    lat_parallel = tl.observe_flush(
        [ChipBurst(c, senses=1, matches=2, bus_match_bytes=128,
                   pcie_bytes=64) for c in range(4)])
    assert tl.sim.stats.senses == 4 and tl.sim.stats.matches == 8
    tl2 = BurstTimeline(tl.params)
    lat_serial = tl2.observe_flush(
        [ChipBurst(0, senses=4, matches=8, bus_match_bytes=512,
                   pcie_bytes=256)])
    assert lat_serial > lat_parallel       # 4 dies overlap their senses
    before = tl.sim.stats.programs
    tl.observe_program(2)
    assert tl.sim.stats.programs == before + 1
    assert tl.write_latencies and tl.energy_pj > 0
