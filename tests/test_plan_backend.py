"""Op.PLAN — fused in-latch range-plan execution — and the lazy result path.

The contract (ISSUE 4): PLAN results are bit-identical across the scalar
(per-pass split reference), batched and sharded backends AND identical to
the per-pass ``evaluate_plan_per_pass`` combine, for exact and approximate
plans; device->host result bytes drop by the plan's pass count; ticket
resolution is lazy (launch outputs stay on-device until the first
``result()``) without changing any observable value.
"""
import numpy as np
import pytest

from repro.backend import (BatchedKernelBackend, ScalarBackend,
                           ShardedSsdBackend, make_backend)
from repro.core.commands import Command, Op
from repro.core.engine import SimChipArray
from repro.core.range_query import (MaskedQuery, RangePlan,
                                    approximate_range,
                                    evaluate_plan_on_pages,
                                    evaluate_plan_per_pass, exact_range)
from repro.frontend import RunConfig, replay
from repro.workload.ycsb import generate

N_PAGES = 12
ENTRIES_PER_PAGE = 300
KEY_SPAN = 2**48


def _page_keys(seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, KEY_SPAN, ENTRIES_PER_PAGE, dtype=np.uint64)
            for _ in range(N_PAGES)]


def _programmed(page_keys, make):
    be = make()
    for p, keys in enumerate(page_keys):
        be.program_entries(p, keys)
    return be


@pytest.fixture(scope="module")
def backends():
    page_keys = _page_keys()
    mk = {
        "scalar": lambda: ScalarBackend(
            SimChipArray(n_chips=4, pages_per_chip=8, device_seed=31)),
        "batched": lambda: BatchedKernelBackend(
            SimChipArray(n_chips=4, pages_per_chip=8, device_seed=31)),
        "sharded4x2": lambda: ShardedSsdBackend.from_geometry(
            channels=4, dies_per_channel=2, pages_per_chip=8,
            device_seed=31),
    }
    return {k: _programmed(page_keys, m) for k, m in mk.items()}, page_keys


def _plans(page_keys):
    allk = np.concatenate(page_keys)
    lo = int(np.percentile(allk, 35))
    hi = int(np.percentile(allk, 65))
    return {
        "exact": exact_range(lo, hi, width=64),
        "approx": approximate_range(lo, hi, width=64),
        "exact_narrow": exact_range(lo, lo + 3, width=64),
        "include_only": RangePlan(include=(MaskedQuery(
            query=int(page_keys[0][0]), mask=0xFFFFFFFFFFFFFFFF),)),
        "match_all": RangePlan(include=(MaskedQuery(query=0, mask=0),)),
    }


# ------------------------------------------------------------------ parity
def test_plan_bit_identical_across_backends_and_per_pass(backends):
    """PLAN == per-pass split combine, on every backend, for exact and
    approximate plans — the Fig 10 in-latch accumulation is semantically
    invisible."""
    bes, page_keys = backends
    pages = list(range(N_PAGES))
    for label, plan in _plans(page_keys).items():
        ref = evaluate_plan_per_pass(bes["scalar"], plan, pages)
        for name, be in bes.items():
            got = evaluate_plan_on_pages(be, plan, pages)
            np.testing.assert_array_equal(ref, got, err_msg=f"{label}/{name}")
        # ...and the combined bitmap agrees with direct key evaluation.
        for p in (0, N_PAGES - 1):
            want = plan.evaluate(page_keys[p])
            from repro.core.bits import unpack_bitmap
            got_bits = unpack_bitmap(ref[p], 512)[8:8 + ENTRIES_PER_PAGE]
            np.testing.assert_array_equal(got_bits.astype(bool), want,
                                          err_msg=f"{label}/page{p}")


def test_plan_burst_is_one_launch_with_dedup(backends):
    """Many pages x few distinct plans = ONE launch; identical plans dedup
    into shared plan groups like identical queries dedup into query rows."""
    bes, page_keys = backends
    plan_a = exact_range(1000, 2**40, width=64)
    plan_b = approximate_range(1000, 2**40, width=64)
    for name in ("batched", "sharded4x2"):
        be = bes[name]
        before = be.stats.kernel_launches
        tickets = [be.submit_plan(Command.plan(p, pl.include, pl.exclude))
                   for pl in (plan_a, plan_b) for p in range(N_PAGES)]
        be.flush()
        assert be.stats.kernel_launches == before + 1
        assert all(t.done for t in tickets)
        # Same plan twice on the same page -> same launch cell, shared copy.
        t1 = be.submit_plan(Command.plan(3, plan_a.include, plan_a.exclude))
        t2 = be.submit_plan(Command.plan(3, plan_a.include, plan_a.exclude))
        rb = be.stats.result_bytes
        be.flush()
        np.testing.assert_array_equal(t1.result().bitmap_words,
                                      t2.result().bitmap_words)
        assert be.stats.result_bytes - rb == 64   # one transfer, not two


def test_plan_result_bytes_drop_by_pass_count(backends):
    """The headline bandwidth claim: fused PLAN ships 64 B/page, the
    per-pass path 64 B/pass/page — an exact result_bytes contract."""
    bes, page_keys = backends
    plan = _plans(page_keys)["exact"]
    assert plan.n_passes > 10
    pages = list(range(N_PAGES))
    be = bes["batched"]
    before = be.stats.result_bytes
    evaluate_plan_on_pages(be, plan, pages)
    fused_bytes = be.stats.result_bytes - before
    before = be.stats.result_bytes
    evaluate_plan_per_pass(be, plan, pages)
    per_pass_bytes = be.stats.result_bytes - before
    assert fused_bytes == 64 * N_PAGES
    assert per_pass_bytes == 64 * plan.n_passes * N_PAGES
    assert per_pass_bytes // fused_bytes == plan.n_passes


def test_plan_validation():
    be = ScalarBackend(SimChipArray(n_chips=1, pages_per_chip=4))
    with pytest.raises(ValueError):
        be.submit_plan(Command.search(0, 123))
    cmd = Command.plan(0, [(5, 0xFF)], [(1, 0x0F)])
    assert cmd.op is Op.PLAN and cmd.n_passes == 2
    # pass pairs accept MaskedQuery objects and raw (q, m) tuples alike
    cmd2 = Command.plan(0, [MaskedQuery(query=5, mask=0xFF)],
                        [MaskedQuery(query=1, mask=0x0F)])
    assert cmd2.plan_include == cmd.plan_include
    assert cmd2.plan_exclude == cmd.plan_exclude


# ------------------------------------------------------------- lazy tickets
def test_lazy_ticket_out_of_order_resolution(backends):
    """Two bursts flushed back-to-back, the first drained AFTER the second:
    lazy batches must resolve independently and bit-identically."""
    bes, page_keys = backends
    be = bes["batched"]
    ref = bes["scalar"]
    cmds_a = [Command.search(p, int(page_keys[p][5])) for p in range(6)]
    cmds_b = [Command.search(p, int(page_keys[p][6])) for p in range(6)]
    ta = [be.submit_search(c) for c in cmds_a]
    be.flush()                               # dispatched, not yet drained
    tb = [be.submit_search(c) for c in cmds_b]
    be.flush()
    assert all(t.done for t in ta + tb)      # resolvable without new flush
    for c, t in list(zip(cmds_b, tb)) + list(zip(cmds_a, ta)):  # B first
        np.testing.assert_array_equal(t.result().bitmap_words,
                                      ref.search(c).bitmap_words)


def test_lazy_ticket_survives_interleaved_reprogram(backends):
    """A reprogram AFTER a flush must not leak into that flush's deferred
    results — the launch captured the pre-write plane snapshot."""
    page_keys = _page_keys(seed=23)
    be = _programmed(page_keys, lambda: BatchedKernelBackend(
        SimChipArray(n_chips=4, pages_per_chip=8, device_seed=9)))
    sc = _programmed(page_keys, lambda: ScalarBackend(
        SimChipArray(n_chips=4, pages_per_chip=8, device_seed=9)))
    probe = Command.search(2, int(page_keys[2][0]))
    want = sc.search(probe)                  # pre-write reference
    t = be.submit_search(probe)
    be.flush()                               # launch dispatched
    be.program_entries(2, page_keys[2][::-1].copy())   # then reprogram
    np.testing.assert_array_equal(t.result().bitmap_words,
                                  want.bitmap_words)
    # ...and a new search sees the new image.
    sc.chips.program_entries(2, page_keys[2][::-1].copy())
    np.testing.assert_array_equal(
        be.search(probe).bitmap_words,
        sc.search(probe).bitmap_words)


def test_lazy_lookup_parity_survives_interleaved_reprogram():
    """CRC verification of a deferred lookup must use the parities as of
    flush time: a reprogram of the value page between flush() and the
    first result() must not flip parity_ok (the launch captured the
    pre-write plane snapshot, so the old parities are the right ones)."""
    rng = np.random.default_rng(3)
    keys = rng.integers(1, 2**50, 100, dtype=np.uint64)
    vals = rng.integers(1, 2**50, 100, dtype=np.uint64)
    for name in ("scalar", "batched"):
        be = make_backend(name, SimChipArray(n_chips=2, pages_per_chip=8,
                                             device_seed=1))
        be.program_entries(0, keys)
        be.program_entries(1, vals)
        t = be.submit_lookup(Command.lookup(0, 1, int(keys[7])))
        g = be.submit_gather(Command.gather(1, 0b110))
        be.flush()
        be.program_entries(1, vals[::-1].copy())    # between flush + drain
        r = t.result()
        assert r.parity_ok and r.value_slot is not None, name
        assert r.value == int(vals[7]).to_bytes(8, "little"), name
        gr = g.result()
        assert gr.parity_ok.all(), name


# ---------------------------------------------------------------- workload
def test_ycsb_scan_replay_bit_identical():
    """YCSB-E scans (op 2) replay through the fused PLAN path and must be
    bit-identical — counts and read values — across all three backends."""
    wl = generate(180, n_key_pages=4, read_ratio=0.7, alpha=0.5, seed=5,
                  scan_ratio=0.1, max_scan_len=40)
    assert (wl.ops == 2).sum() > 0
    outs = {}
    for name, make in {
        "scalar": lambda: make_backend("scalar", SimChipArray(
            n_chips=4, pages_per_chip=16, device_seed=3)),
        "batched": lambda: make_backend("batched", SimChipArray(
            n_chips=4, pages_per_chip=16, device_seed=3)),
        "sharded2x2": lambda: ShardedSsdBackend.from_geometry(
            channels=2, dies_per_channel=2, pages_per_chip=16,
            device_seed=3, timeline=True),
    }.items():
        outs[name] = replay(wl, make(), RunConfig(burst=32, fused=True))
    ref = outs["scalar"]
    n_keys = 4 * 504
    for r in outs.values():
        np.testing.assert_array_equal(ref.read_values, r.read_values)
        np.testing.assert_array_equal(ref.scan_counts, r.scan_counts)
        assert r.n_scans == ref.n_scans > 0
    # All stored keys in a scan window exist, so counts == window size.
    for qi in np.nonzero(wl.ops == 2)[0]:
        lo = int(wl.keys[qi]) + 1
        hi = min(lo + int(wl.scan_lens[qi]), n_keys + 1)
        assert ref.scan_counts[qi] == hi - lo
    # Timeline coupling still holds with scans in the stream.
    sh = outs["sharded2x2"]
    assert sh.burst_latencies_ns is not None
    assert len(sh.burst_latencies_ns) == sh.flushes


def test_scan_free_generate_stream_unchanged():
    """scan_ratio=0 must leave the historical op/key stream bit-identical
    (the RNG consumption is untouched)."""
    a = generate(200, n_key_pages=4, read_ratio=0.8, alpha=0.5, seed=11)
    b = generate(200, n_key_pages=4, read_ratio=0.8, alpha=0.5, seed=11,
                 scan_ratio=0.0)
    np.testing.assert_array_equal(a.ops, b.ops)
    np.testing.assert_array_equal(a.keys, b.keys)
    assert b.scan_lens is None
