"""Serving: continuous batching engine + the SiM-paged KV cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models.model import init_model, prefill, decode_step
from repro.serve.batching import Request, ServeEngine
from repro.serve.kvcache import SimPagedKVCache, TABLE_CODEC


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(reduced_config(ARCHS["qwen3-4b"]),
                              dtype="float32")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def test_engine_continuous_batching(small_model):
    params, cfg = small_model
    engine = ServeEngine(params, cfg, max_slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    for rid in range(5):
        engine.submit(Request(req_id=rid,
                              prompt=rng.integers(0, cfg.vocab_size,
                                                  size=6).tolist(),
                              max_new_tokens=4))
    completions = engine.run()
    assert len(completions) == 5
    assert all(len(c.tokens) == 4 for c in completions)
    # slots never exceeded, queue drained
    assert engine.steps >= 3 and not engine.queue and not engine.slots


def test_engine_matches_plain_decode(small_model):
    """Engine generation == direct prefill+decode loop for one request."""
    params, cfg = small_model
    prompt = [5, 9, 13, 21]
    engine = ServeEngine(params, cfg, max_slots=1, cache_len=64)
    engine.submit(Request(req_id=0, prompt=prompt, max_new_tokens=5))
    toks_engine = engine.run()[0].tokens

    logits, caches = prefill(params, cfg, jnp.asarray([prompt], jnp.int32),
                             64)
    toks = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(4):
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 caches, pos)
        toks.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    assert toks_engine == toks


# ------------------------------------------------------- SiM paged KV cache

def _mk_cache(cfg, **kw):
    return SimPagedKVCache(cfg, n_pages=64, page_tokens=4, **kw)


def test_paged_allocate_lookup_roundtrip(small_model):
    _, cfg = small_model
    pc = _mk_cache(cfg)
    p0 = pc.allocate(seq_id=7, logical_block=0)
    p1 = pc.allocate(seq_id=7, logical_block=1)
    p2 = pc.allocate(seq_id=9, logical_block=0)
    assert pc.lookup(7, 0) == p0
    assert pc.lookup(7, 1) == p1
    assert pc.lookup(9, 0) == p2
    assert pc.lookup(7, 2) is None
    assert pc.lookup(8, 0) is None
    assert pc.stats.searches >= 5      # lookups are real search commands


def test_paged_write_gather_roundtrip(small_model):
    _, cfg = small_model
    pc = _mk_cache(cfg)
    rng = np.random.default_rng(1)
    L, K, H = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    toks = [jnp.asarray(rng.normal(size=(L, K, H)), jnp.float32)
            for _ in range(6)]
    for pos, t in enumerate(toks):
        pc.write_token(3, pos, t, t * 2)
    k, v = pc.gather_sequence(3, 6)
    assert k.shape == (L, 6, K, H)
    for pos, t in enumerate(toks):
        np.testing.assert_allclose(np.asarray(k[:, pos]), np.asarray(t),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(v[:, pos]),
                                   np.asarray(t) * 2, atol=1e-6)


def test_paged_free_sequence_recycles(small_model):
    _, cfg = small_model
    pc = _mk_cache(cfg)
    for pos in range(8):        # 2 pages
        pc.write_token(11, pos, jnp.zeros((cfg.n_layers, cfg.n_kv_heads,
                                           cfg.head_dim)),
                       jnp.zeros((cfg.n_layers, cfg.n_kv_heads,
                                  cfg.head_dim)))
    free_before = len(pc._free)
    assert pc.free_sequence(11) == 2
    assert len(pc._free) == free_before + 2
    assert pc.lookup(11, 0) is None


def test_paged_engine_end_to_end(small_model):
    """Engine with SiM-paged mirror: generation unchanged, pages recycled."""
    params, cfg = small_model
    pc = _mk_cache(cfg)
    engine = ServeEngine(params, cfg, max_slots=2, cache_len=32,
                         paged_cache=pc)
    plain = ServeEngine(params, cfg, max_slots=2, cache_len=32)
    rng = np.random.default_rng(2)
    reqs = [Request(req_id=r, prompt=rng.integers(
        0, cfg.vocab_size, size=5).tolist(), max_new_tokens=3)
        for r in range(3)]
    for r in reqs:
        engine.submit(dataclasses.replace(r))
        plain.submit(dataclasses.replace(r))
    out_paged = {c.req_id: c.tokens for c in engine.run()}
    out_plain = {c.req_id: c.tokens for c in plain.run()}
    assert out_paged == out_plain
    assert pc.stats.pages_allocated > 0
    assert pc.stats.pages_freed == pc.stats.pages_allocated  # all recycled
    assert pc.stats.searches > 0


def test_table_codec_fields():
    k = TABLE_CODEC.encode(seq=123, block=45, phys=67)
    assert TABLE_CODEC.decode(k, "seq") == 123
    assert TABLE_CODEC.decode(k, "block") == 45
    assert TABLE_CODEC.decode(k, "phys") == 67
