"""Cross-backend parity: the batched Pallas backend must be bit-identical
to the scalar SimChip reference on every programmed page — packed search
bitmaps, match counts, gather chunk bytes/ids/parities — including the
randomized=True in-kernel stream regeneration across chips with different
device seeds, and end-to-end through the index and workload layers.
"""
import numpy as np
import pytest

from repro.backend import BatchedKernelBackend, ScalarBackend, make_backend
from repro.core.bits import chunk_bitmap_from_slot_bitmap, pair_to_u64
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.core.page import mask_header_slots
from repro.core.range_query import evaluate_plan_on_pages, exact_range
from repro.index.btree import SimBTree
from repro.index.hashindex import SimHashIndex
from repro.frontend import RunConfig, replay
from repro.workload.ycsb import generate

N_PAGES = 12
ENTRIES_PER_PAGE = 300


def _programmed_pair(seed=7):
    """Two identically-programmed chip arrays (one per backend)."""
    arrays = []
    rng = np.random.default_rng(seed)
    page_keys = [rng.integers(1, 2**62, ENTRIES_PER_PAGE, dtype=np.uint64)
                 for _ in range(N_PAGES)]
    for _ in range(2):
        # several chips -> staged pages span different device seeds, so the
        # per-page seed operand of the search kernel is really exercised
        arr = SimChipArray(n_chips=5, pages_per_chip=8, device_seed=31)
        for p, keys in enumerate(page_keys):
            arr.program_entries(p, keys)
        arrays.append(arr)
    return arrays[0], arrays[1], page_keys


@pytest.fixture(scope="module")
def backends():
    arr_s, arr_b, page_keys = _programmed_pair()
    return ScalarBackend(arr_s), BatchedKernelBackend(arr_b), page_keys


def test_search_bitmaps_bit_identical(backends):
    sb, bb, page_keys = backends
    rng = np.random.default_rng(1)
    cmds = []
    for _ in range(48):
        p = int(rng.integers(0, N_PAGES))
        if rng.random() < 0.5:                      # planted hit
            q = int(page_keys[p][rng.integers(0, ENTRIES_PER_PAGE)])
            mask = 0xFFFFFFFFFFFFFFFF
        else:                                       # masked / miss
            q = int(rng.integers(1, 2**62))
            mask = int(rng.integers(0, 2**64, dtype=np.uint64))
        cmds.append(Command.search(p, q, mask))
    cmds.append(Command.search(0, 0, 0))            # §V-D match-all

    ts = [sb.submit_search(c) for c in cmds]
    tb = [bb.submit_search(c) for c in cmds]
    sb.flush()
    bb.flush()
    for a, b in zip(ts, tb):
        ra, rb = a.result(), b.result()
        np.testing.assert_array_equal(ra.bitmap_words, rb.bitmap_words)
        assert ra.match_count == rb.match_count


def test_gather_chunks_ids_parity_bit_identical(backends):
    sb, bb, page_keys = backends
    rng = np.random.default_rng(2)
    cmds = []
    for p in range(N_PAGES):
        # random multi-chunk bitmaps, plus the empty and full selections
        cmds.append(Command.gather(p, int(rng.integers(0, 2**64,
                                                       dtype=np.uint64))))
    cmds.append(Command.gather(0, 0))
    cmds.append(Command.gather(1, 0xFFFFFFFFFFFFFFFF))

    ts = [sb.submit_gather(c) for c in cmds]
    tb = [bb.submit_gather(c) for c in cmds]
    sb.flush()
    bb.flush()
    for a, b in zip(ts, tb):
        ra, rb = a.result(), b.result()
        np.testing.assert_array_equal(ra.chunks, rb.chunks)
        np.testing.assert_array_equal(ra.chunk_ids, rb.chunk_ids)
        np.testing.assert_array_equal(ra.parity_ok, rb.parity_ok)
        assert ra.parity_ok.all()                   # clean pages


def test_search_then_gather_pipeline(backends):
    """The Fig 8 point-lookup command sequence end to end on both."""
    sb, bb, page_keys = backends
    p = 3
    q = int(page_keys[p][17])
    for be in (sb, bb):
        resp = be.search(Command.search(p, q))
        bitmap = mask_header_slots(resp.bitmap_words)
        cb = int(pair_to_u64(*chunk_bitmap_from_slot_bitmap(bitmap)))
        g = be.gather(Command.gather(p, cb))
        assert g.parity_ok.all()
    ga = sb.gather(Command.gather(p, 0b1010))
    gb = bb.gather(Command.gather(p, 0b1010))
    np.testing.assert_array_equal(ga.chunks, gb.chunks)


def test_ticket_result_autoflushes(backends):
    sb, bb, page_keys = backends
    t = bb.submit_search(Command.search(0, int(page_keys[0][0])))
    assert not t.done and bb.pending == 1
    resp = t.result()                               # implicit flush
    assert t.done and bb.pending == 0
    ref = sb.search(Command.search(0, int(page_keys[0][0])))
    np.testing.assert_array_equal(resp.bitmap_words, ref.bitmap_words)


def test_range_plan_parity(backends):
    sb, bb, page_keys = backends
    lo = int(np.percentile(page_keys[0], 30))
    hi = int(np.percentile(page_keys[0], 60))
    plan = exact_range(lo, hi, width=64)
    pages = list(range(N_PAGES))
    out_s = evaluate_plan_on_pages(sb, plan, pages)
    out_b = evaluate_plan_on_pages(bb, plan, pages)
    np.testing.assert_array_equal(out_s, out_b)
    assert bb.stats.kernel_launches > 0


def test_batched_launch_amortization(backends):
    """A burst of searches over shared pages is one launch (§IV-E)."""
    _, bb, page_keys = backends
    before = bb.stats.kernel_launches
    tickets = [bb.submit_search(Command.search(p, int(page_keys[p][i])))
               for p in range(N_PAGES) for i in range(4)]
    bb.flush()
    assert bb.stats.kernel_launches == before + 1
    assert all(t.done for t in tickets)


def _index_dataset():
    rng = np.random.default_rng(5)
    keys = (rng.choice(10**9, size=1200, replace=False) + 1).astype(np.uint64)
    return keys, keys * np.uint64(13)


@pytest.mark.parametrize("backend_name", ["scalar", "batched"])
def test_btree_results_identical_on_both_backends(backend_name):
    keys, values = _index_dataset()
    be = make_backend(backend_name,
                      SimChipArray(n_chips=8, pages_per_chip=64))
    bt = SimBTree(be)
    bt.bulk_load(keys, values)
    probes = [int(k) for k in keys[::97]] + [int(keys[0]) + 1]
    got = bt.lookup_batch(probes)
    want = [int(k) * 13 if k in set(keys.tolist()) else None for k in probes]
    assert got == want
    lo, hi = int(np.percentile(keys, 45)), int(np.percentile(keys, 50))
    expect = sorted((int(k), int(k) * 13) for k in keys
                    if lo <= int(k) < hi)
    assert sorted(bt.range_query(lo, hi)) == expect


def test_hash_index_parity():
    keys, values = _index_dataset()
    results = []
    for name in ("scalar", "batched"):
        h = SimHashIndex(make_backend(
            name, SimChipArray(n_chips=8, pages_per_chip=512)))
        for k, v in zip(keys[:800], values[:800]):
            h.insert(int(k), int(v))
        results.append(h.lookup_batch([int(k) for k in keys[:800:23]]
                                      + [10**15 + 3]))
    assert results[0] == results[1]
    assert results[0][-1] is None
    assert results[0][0] == int(keys[0]) * 13


def test_fused_lookup_parity_vs_scalar_split(backends):
    """The fused single-launch lookup path must be bit-identical to the
    scalar reference's split search+gather — bitmap, slot, value bytes and
    inner-code verdict — including misses and multi-match pages."""
    sb, bb, page_keys = backends
    rng = np.random.default_rng(4)
    cmds = []
    for _ in range(24):
        kp = int(rng.integers(0, N_PAGES // 2))
        vp = kp + N_PAGES // 2
        if rng.random() < 0.7:                      # planted hit
            q = int(page_keys[kp][rng.integers(0, ENTRIES_PER_PAGE)])
        else:                                       # miss
            q = int(rng.integers(2**62, 2**63))
        cmds.append(Command.lookup(kp, vp, q))

    ts = [sb.submit_lookup(c) for c in cmds]
    tb = [bb.submit_lookup(c) for c in cmds]
    launches = bb.stats.kernel_launches
    sb.flush()
    bb.flush()
    assert bb.stats.kernel_launches == launches + 1   # whole burst, 1 launch
    saw_hit = saw_miss = False
    for _c, a, b in zip(cmds, ts, tb):
        ra, rb = a.result(), b.result()
        np.testing.assert_array_equal(ra.search.bitmap_words,
                                      rb.search.bitmap_words)
        assert ra.search.match_count == rb.search.match_count
        assert ra.value_slot == rb.value_slot
        assert ra.value == rb.value
        assert ra.parity_ok == rb.parity_ok
        saw_hit |= ra.value_slot is not None
        saw_miss |= ra.value_slot is None
    assert saw_hit and saw_miss

    # The fused lookup must also agree with an explicit split decomposition.
    c = cmds[0]
    resp = bb.lookup(c)
    s = bb.search(Command.search(c.page_addr, pair_to_u64(*c.query)))
    np.testing.assert_array_equal(resp.search.bitmap_words, s.bitmap_words)
    if resp.value_slot is not None:
        g = bb.gather(Command.gather(
            c.value_page, 1 << (resp.value_slot // 8)))
        off = (resp.value_slot % 8) * 8
        assert resp.value == bytes(g.chunks[0][off:off + 8])


def test_planestore_invalidation_on_reprogram():
    """program -> search -> reprogram same page -> search must reflect the
    new image on both backends, and the batched backend must restage only
    the dirty row (4 KiB), nothing else."""
    rng = np.random.default_rng(9)
    keys_a = rng.integers(1, 2**62, 100, dtype=np.uint64)
    keys_b = rng.integers(1, 2**62, 100, dtype=np.uint64)
    arrays = [SimChipArray(n_chips=3, pages_per_chip=8, device_seed=17)
              for _ in range(2)]
    backends_ = [ScalarBackend(arrays[0]), BatchedKernelBackend(arrays[1])]
    for arr in arrays:
        for p in range(6):
            arr.program_entries(p, keys_a)

    probe = Command.search(2, int(keys_b[7]))       # only in the NEW image
    first = [be.search(probe) for be in backends_]
    np.testing.assert_array_equal(first[0].bitmap_words,
                                  first[1].bitmap_words)
    assert first[0].match_count == 0

    bb = backends_[1]
    warm = bb.stats.staged_bytes
    for arr in arrays:
        arr.program_entries(2, keys_b)              # dirties one arena row
    second = [be.search(probe) for be in backends_]
    np.testing.assert_array_equal(second[0].bitmap_words,
                                  second[1].bitmap_words)
    assert second[0].match_count == 1
    assert bb.stats.staged_bytes - warm == 4096     # exactly the dirty row

    # ...and further searches of the (clean, resident) page restage nothing.
    warm = bb.stats.staged_bytes
    resp = bb.search(Command.search(2, int(keys_a[0])))   # old key: miss now
    assert resp.match_count == 0
    assert bb.stats.staged_bytes == warm


def test_planestore_zero_restage_after_warmup(backends):
    """Steady-state flushes of a warm working set ship zero page bytes —
    only query operands cross host->device (the §III-B in-array analogue)."""
    _, bb, page_keys = backends
    cmds = [Command.search(p, int(page_keys[p][3])) for p in range(N_PAGES)]
    for c in cmds:
        bb.submit_search(c)
    bb.flush()                                      # warm the arena
    for _ in range(3):
        before = bb.stats.staged_bytes
        for c in cmds:
            bb.submit_search(c)
        bb.flush()
        assert bb.stats.staged_bytes == before


def test_ycsb_run_functional_fused_identical():
    """Fused replay: bit-identical read values on every backend x mode, and
    the fused burst is ONE kernel launch (vs 2 on the split path)."""
    wl = generate(300, n_key_pages=6, read_ratio=0.8, alpha=0.5, seed=11)
    outs = {}
    for name, fused in (("scalar", False), ("scalar", True),
                        ("batched", False), ("batched", True)):
        arr = SimChipArray(n_chips=4, pages_per_chip=16, device_seed=3)
        outs[(name, fused)] = replay(wl, make_backend(name, arr),
                                     RunConfig(burst=32, fused=fused))
    ref = outs[("scalar", False)]
    for r in outs.values():
        np.testing.assert_array_equal(ref.read_values, r.read_values)
        np.testing.assert_array_equal(ref.read_hits, r.read_hits)
    split, fused = outs[("batched", False)], outs[("batched", True)]
    assert fused.kernel_launches == fused.flushes          # 1 launch/burst
    assert split.kernel_launches == 2 * fused.kernel_launches
    assert fused.staged_bytes > 0 and ref.staged_bytes == 0


def test_ycsb_run_functional_identical():
    """Full workload replay: identical read values on both backends, and
    the batched backend actually batches (2 launches per read burst)."""
    wl = generate(300, n_key_pages=6, read_ratio=0.8, alpha=0.5, seed=11)
    outs = {}
    for name in ("scalar", "batched"):
        arr = SimChipArray(n_chips=4, pages_per_chip=16, device_seed=3)
        outs[name] = replay(wl, make_backend(name, arr),
                            RunConfig(burst=32))
    np.testing.assert_array_equal(outs["scalar"].read_values,
                                  outs["batched"].read_values)
    np.testing.assert_array_equal(outs["scalar"].read_hits,
                                  outs["batched"].read_hits)
    assert outs["scalar"].read_hits[wl.ops == 0].all()
    assert outs["scalar"].kernel_launches == 0
    assert outs["batched"].kernel_launches > 0
    assert outs["batched"].kernel_launches <= outs["batched"].flushes
