"""Cross-backend parity: the batched Pallas backend must be bit-identical
to the scalar SimChip reference on every programmed page — packed search
bitmaps, match counts, gather chunk bytes/ids/parities — including the
randomized=True in-kernel stream regeneration across chips with different
device seeds, and end-to-end through the index and workload layers.
"""
import numpy as np
import pytest

from repro.backend import BatchedKernelBackend, ScalarBackend, make_backend
from repro.core.bits import chunk_bitmap_from_slot_bitmap, pair_to_u64
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.core.page import mask_header_slots
from repro.core.range_query import evaluate_plan_on_pages, exact_range
from repro.index.btree import SimBTree
from repro.index.hashindex import SimHashIndex
from repro.workload.runner import run_functional
from repro.workload.ycsb import generate

N_PAGES = 12
ENTRIES_PER_PAGE = 300


def _programmed_pair(seed=7):
    """Two identically-programmed chip arrays (one per backend)."""
    arrays = []
    rng = np.random.default_rng(seed)
    page_keys = [rng.integers(1, 2**62, ENTRIES_PER_PAGE, dtype=np.uint64)
                 for _ in range(N_PAGES)]
    for _ in range(2):
        # several chips -> staged pages span different device seeds, so the
        # per-page seed operand of the search kernel is really exercised
        arr = SimChipArray(n_chips=5, pages_per_chip=8, device_seed=31)
        for p, keys in enumerate(page_keys):
            arr.program_entries(p, keys)
        arrays.append(arr)
    return arrays[0], arrays[1], page_keys


@pytest.fixture(scope="module")
def backends():
    arr_s, arr_b, page_keys = _programmed_pair()
    return ScalarBackend(arr_s), BatchedKernelBackend(arr_b), page_keys


def test_search_bitmaps_bit_identical(backends):
    sb, bb, page_keys = backends
    rng = np.random.default_rng(1)
    cmds = []
    for _ in range(48):
        p = int(rng.integers(0, N_PAGES))
        if rng.random() < 0.5:                      # planted hit
            q = int(page_keys[p][rng.integers(0, ENTRIES_PER_PAGE)])
            mask = 0xFFFFFFFFFFFFFFFF
        else:                                       # masked / miss
            q = int(rng.integers(1, 2**62))
            mask = int(rng.integers(0, 2**64, dtype=np.uint64))
        cmds.append(Command.search(p, q, mask))
    cmds.append(Command.search(0, 0, 0))            # §V-D match-all

    ts = [sb.submit_search(c) for c in cmds]
    tb = [bb.submit_search(c) for c in cmds]
    sb.flush()
    bb.flush()
    for a, b in zip(ts, tb):
        ra, rb = a.result(), b.result()
        np.testing.assert_array_equal(ra.bitmap_words, rb.bitmap_words)
        assert ra.match_count == rb.match_count


def test_gather_chunks_ids_parity_bit_identical(backends):
    sb, bb, page_keys = backends
    rng = np.random.default_rng(2)
    cmds = []
    for p in range(N_PAGES):
        # random multi-chunk bitmaps, plus the empty and full selections
        cmds.append(Command.gather(p, int(rng.integers(0, 2**64,
                                                       dtype=np.uint64))))
    cmds.append(Command.gather(0, 0))
    cmds.append(Command.gather(1, 0xFFFFFFFFFFFFFFFF))

    ts = [sb.submit_gather(c) for c in cmds]
    tb = [bb.submit_gather(c) for c in cmds]
    sb.flush()
    bb.flush()
    for a, b in zip(ts, tb):
        ra, rb = a.result(), b.result()
        np.testing.assert_array_equal(ra.chunks, rb.chunks)
        np.testing.assert_array_equal(ra.chunk_ids, rb.chunk_ids)
        np.testing.assert_array_equal(ra.parity_ok, rb.parity_ok)
        assert ra.parity_ok.all()                   # clean pages


def test_search_then_gather_pipeline(backends):
    """The Fig 8 point-lookup command sequence end to end on both."""
    sb, bb, page_keys = backends
    p = 3
    q = int(page_keys[p][17])
    for be in (sb, bb):
        resp = be.search(Command.search(p, q))
        bitmap = mask_header_slots(resp.bitmap_words)
        cb = int(pair_to_u64(*chunk_bitmap_from_slot_bitmap(bitmap)))
        g = be.gather(Command.gather(p, cb))
        assert g.parity_ok.all()
    ga = sb.gather(Command.gather(p, 0b1010))
    gb = bb.gather(Command.gather(p, 0b1010))
    np.testing.assert_array_equal(ga.chunks, gb.chunks)


def test_ticket_result_autoflushes(backends):
    sb, bb, page_keys = backends
    t = bb.submit_search(Command.search(0, int(page_keys[0][0])))
    assert not t.done and bb.pending == 1
    resp = t.result()                               # implicit flush
    assert t.done and bb.pending == 0
    ref = sb.search(Command.search(0, int(page_keys[0][0])))
    np.testing.assert_array_equal(resp.bitmap_words, ref.bitmap_words)


def test_range_plan_parity(backends):
    sb, bb, page_keys = backends
    lo = int(np.percentile(page_keys[0], 30))
    hi = int(np.percentile(page_keys[0], 60))
    plan = exact_range(lo, hi, width=64)
    pages = list(range(N_PAGES))
    out_s = evaluate_plan_on_pages(sb, plan, pages)
    out_b = evaluate_plan_on_pages(bb, plan, pages)
    np.testing.assert_array_equal(out_s, out_b)
    assert bb.stats.kernel_launches > 0


def test_batched_launch_amortization(backends):
    """A burst of searches over shared pages is one launch (§IV-E)."""
    _, bb, page_keys = backends
    before = bb.stats.kernel_launches
    tickets = [bb.submit_search(Command.search(p, int(page_keys[p][i])))
               for p in range(N_PAGES) for i in range(4)]
    bb.flush()
    assert bb.stats.kernel_launches == before + 1
    assert all(t.done for t in tickets)


def _index_dataset():
    rng = np.random.default_rng(5)
    keys = (rng.choice(10**9, size=1200, replace=False) + 1).astype(np.uint64)
    return keys, keys * np.uint64(13)


@pytest.mark.parametrize("backend_name", ["scalar", "batched"])
def test_btree_results_identical_on_both_backends(backend_name):
    keys, values = _index_dataset()
    be = make_backend(backend_name,
                      SimChipArray(n_chips=8, pages_per_chip=64))
    bt = SimBTree(be)
    bt.bulk_load(keys, values)
    probes = [int(k) for k in keys[::97]] + [int(keys[0]) + 1]
    got = bt.lookup_batch(probes)
    want = [int(k) * 13 if k in set(keys.tolist()) else None for k in probes]
    assert got == want
    lo, hi = int(np.percentile(keys, 45)), int(np.percentile(keys, 50))
    expect = sorted((int(k), int(k) * 13) for k in keys
                    if lo <= int(k) < hi)
    assert sorted(bt.range_query(lo, hi)) == expect


def test_hash_index_parity():
    keys, values = _index_dataset()
    results = []
    for name in ("scalar", "batched"):
        h = SimHashIndex(make_backend(
            name, SimChipArray(n_chips=8, pages_per_chip=512)))
        for k, v in zip(keys[:800], values[:800]):
            h.insert(int(k), int(v))
        results.append(h.lookup_batch([int(k) for k in keys[:800:23]]
                                      + [10**15 + 3]))
    assert results[0] == results[1]
    assert results[0][-1] is None
    assert results[0][0] == int(keys[0]) * 13


def test_ycsb_run_functional_identical():
    """Full workload replay: identical read values on both backends, and
    the batched backend actually batches (2 launches per read burst)."""
    wl = generate(300, n_key_pages=6, read_ratio=0.8, alpha=0.5, seed=11)
    outs = {}
    for name in ("scalar", "batched"):
        arr = SimChipArray(n_chips=4, pages_per_chip=16, device_seed=3)
        outs[name] = run_functional(wl, make_backend(name, arr), burst=32)
    np.testing.assert_array_equal(outs["scalar"].read_values,
                                  outs["batched"].read_values)
    np.testing.assert_array_equal(outs["scalar"].read_hits,
                                  outs["batched"].read_hits)
    assert outs["scalar"].read_hits[wl.ops == 0].all()
    assert outs["scalar"].kernel_launches == 0
    assert outs["batched"].kernel_launches > 0
    assert outs["batched"].kernel_launches <= outs["batched"].flushes
