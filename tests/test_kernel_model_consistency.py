"""Cross-layer consistency: the Pallas flash-attention kernel agrees with
the model's XLA attention path (the one the dry-run lowers), including GQA
grouping, causal masks and sliding windows — proving the kernel is a
drop-in device-side replacement for the serving/training hot spot.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.kernels.flash_attention.ops import flash_attention
from repro.models.layers import _attend, causal_mask_bias


def _case(h, kv, window):
    cfg = dataclasses.replace(
        reduced_config(ARCHS["granite-3-8b"]), dtype="float32",
        n_heads=h, n_kv_heads=kv, head_dim=32,
        sliding_window=window)
    return cfg


@pytest.mark.parametrize("h,kv", [(4, 2), (8, 2), (4, 4)])
@pytest.mark.parametrize("window", [None, 64])
def test_kernel_matches_model_attention(h, kv, window):
    cfg = _case(h, kv, window)
    rng = np.random.default_rng(0)
    B, S, D = 2, 256, 32
    q = jnp.asarray(rng.normal(size=(B, S, h, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, kv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, kv, D)), jnp.float32)

    bias = causal_mask_bias(S, S, window, 0)
    model_out = _attend(q, k, v, bias, cfg)            # XLA path
    kernel_out = flash_attention(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64)   # Pallas path
    np.testing.assert_allclose(np.asarray(model_out),
                               np.asarray(kernel_out), atol=3e-5, rtol=3e-5)


def test_kernel_matches_model_with_head_padding():
    """Padded-heads layout (zero q heads) flows through both paths."""
    cfg = _case(4, 2, None)
    rng = np.random.default_rng(1)
    B, S, D = 1, 128, 32
    q = jnp.asarray(rng.normal(size=(B, S, 6, D)), jnp.float32)
    q = q.at[:, :, 4:].set(0.0)                        # two "padded" heads
    k = jnp.asarray(rng.normal(size=(B, S, 2, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, 2, D)), jnp.float32)
    bias = causal_mask_bias(S, S, None, 0)
    cfg6 = dataclasses.replace(cfg, n_heads=6, n_kv_heads=2)
    model_out = _attend(q, k, v, bias, cfg6)
    kernel_out = flash_attention(q, k, v, causal=True, block_q=64,
                                 block_k=64)
    np.testing.assert_allclose(np.asarray(model_out),
                               np.asarray(kernel_out), atol=3e-5, rtol=3e-5)
