"""Tests: page cache, SSD simulator, workload generator, deadline scheduler."""
import numpy as np

from repro.cache.pagecache import PageCache
from repro.core.commands import Command
from repro.core.scheduler import DeadlineScheduler
from repro.flash.params import DEFAULT_PARAMS, FlashParams
from repro.flash.ssd import SSDSim
from repro.workload.runner import run
from repro.workload.ycsb import concentration_table, generate, zipf_probs


# ------------------------------------------------------------- page cache

def test_cache_lru_eviction_order():
    c = PageCache(2)
    assert c.insert(1, dirty=False) == []
    assert c.insert(2, dirty=False) == []
    c.lookup(1)                                   # 1 becomes MRU
    ev = c.insert(3, dirty=False)
    assert ev == [(2, False)]


def test_cache_write_absorption():
    c = PageCache(4)
    c.insert(1, dirty=True)
    c.insert(1, dirty=True)
    c.insert(1, dirty=True)
    assert c.stats.absorbed_writes == 2
    assert c.dirty_count == 1


def test_cache_dirty_eviction_flagged():
    c = PageCache(1)
    c.insert(1, dirty=True)
    ev = c.insert(2, dirty=False)
    assert ev == [(1, True)]
    assert c.stats.dirty_evictions == 1


def test_cache_dirty_budget_forces_writeback():
    c = PageCache(10, max_dirty_fraction=0.2)     # budget = 2 dirty pages
    assert c.insert(1, dirty=True) == []
    assert c.insert(2, dirty=True) == []
    ev = c.insert(3, dirty=True)                  # over budget -> LRU dirty
    assert ev == [(1, True)]
    assert c.dirty_count == 2


def test_cache_zero_capacity_noop():
    c = PageCache(0)
    assert not c.lookup(5)
    assert c.insert(5, dirty=True) == []
    assert len(c) == 0


def test_cache_read_hit_keeps_dirty_bit():
    c = PageCache(4)
    c.insert(1, dirty=True)
    assert c.lookup(1)
    ev = c.insert(2, dirty=False)
    c.insert(3, dirty=False)
    c.insert(4, dirty=False)
    ev = c.insert(5, dirty=False)
    assert ev == [(1, True)]          # still dirty when finally evicted


# ---------------------------------------------------------------- SSD sim

def _mini_params():
    return FlashParams(channels=2, dies_per_channel=2, blocks_per_plane=4,
                       pages_per_block=64)


def test_sim_read_is_64x_less_pcie_than_baseline():
    p = _mini_params()
    b = SSDSim(p, n_index_pages=128, cache_pages=0, system="baseline")
    s = SSDSim(p, n_index_pages=128, cache_pages=0, system="sim")
    b.read(3, 67, 0.0)
    s.read(3, 67, 0.0)
    assert b.stats.pcie_bytes == 8192
    assert s.stats.pcie_bytes == 128              # 64 B bitmap + 64 B chunk
    assert b.stats.pcie_bytes / s.stats.pcie_bytes == 64


def test_open_page_reuse_skips_sense():
    p = _mini_params()
    s = SSDSim(p, n_index_pages=128, cache_pages=0, system="sim")
    s.read(3, 66, 0.0)              # dies 3 and 2 (4-die mini geometry)
    senses = s.stats.senses
    s.read(3, 66, 1e6)                            # same pages latched
    assert s.stats.senses == senses               # no new sense
    assert s.stats.open_page_hits >= 2


def test_program_invalidates_open_page():
    p = _mini_params()
    s = SSDSim(p, n_index_pages=128, cache_pages=0, system="sim")
    s.read(4, 69, 0.0)              # dies 0 and 1
    senses = s.stats.senses
    s._program(4, 1e6)                            # program on same die+page
    s.read(4, 69, 2e6)
    assert s.stats.senses > senses


def test_write_no_cache_programs_immediately():
    p = _mini_params()
    s = SSDSim(p, n_index_pages=128, cache_pages=0, system="sim")
    s.submit_write(5, 69, 0.0)
    assert s.stats.programs == 2


def test_baseline_read_priority_timelines_independent():
    p = _mini_params()
    s = SSDSim(p, n_index_pages=128, cache_pages=0, system="sim")
    # saturate die 0 with programs, then read from it: sense not delayed
    for _ in range(4):
        s._program(0, 0.0)
    t = s._sense(0, 0.0)
    assert t == p.t_read_ns                       # read-priority suspend


def test_energy_accounting_positive_and_split():
    p = _mini_params()
    s = SSDSim(p, n_index_pages=128, cache_pages=0, system="sim")
    s.read(3, 67, 0.0)
    e = s.energy
    assert e.sense_pj > 0 and e.bus_pj > 0 and e.match_pj > 0
    assert e.program_pj == 0


# ---------------------------------------------------------------- workload

def test_zipf_probs_normalized_and_monotone():
    pr = zipf_probs(1000, 0.9)
    assert abs(pr.sum() - 1.0) < 1e-9
    assert (np.diff(pr) <= 0).all()


def test_concentration_table_shape():
    t = concentration_table(10_000, 0.9)
    assert t.shape == (4,) and t[0] > t[3]


def test_generate_read_ratio_and_page_mapping():
    wl = generate(20_000, n_key_pages=64, read_ratio=0.6, alpha=0.5, seed=3)
    assert abs((wl.ops == 0).mean() - 0.6) < 0.02
    assert wl.key_pages.max() < 64
    assert (wl.value_pages >= 64).all() and (wl.value_pages < 128).all()
    # key/value pages land on different dies for every die count we use
    assert ((wl.key_pages % 16) != (wl.value_pages % 16)).all()


def test_runner_produces_consistent_result():
    wl = generate(2000, n_key_pages=128, read_ratio=0.5, alpha=0.5, seed=7)
    r = run(wl, params=DEFAULT_PARAMS, system="sim", cache_coverage=0.25)
    assert r.qps > 0
    assert r.read_p99_ns >= r.read_median_ns >= 0
    assert r.energy_pj > 0


def test_runner_deterministic():
    wl = generate(1500, n_key_pages=128, read_ratio=0.5, alpha=0.9, seed=9)
    r1 = run(wl, params=DEFAULT_PARAMS, system="baseline", cache_coverage=0.1)
    r2 = run(wl, params=DEFAULT_PARAMS, system="baseline", cache_coverage=0.1)
    assert r1.qps == r2.qps and r1.energy_pj == r2.energy_pj


# ------------------------------------------------------ deadline scheduler

def test_deadline_scheduler_batches_same_page():
    sch = DeadlineScheduler(deadline_ns=4000)
    sch.submit(Command.search(7, 1), now_ns=0)
    sch.submit(Command.search(7, 2), now_ns=1000)
    sch.submit(Command.search(9, 3), now_ns=2000)
    batches = list(sch.pop_expired(now_ns=4000))
    assert len(batches) == 1 and len(batches[0]) == 2
    assert all(c.page_addr == 7 for c in batches[0])
    batches2 = list(sch.pop_expired(now_ns=7000))
    assert len(batches2) == 1 and batches2[0][0].page_addr == 9


def test_deadline_scheduler_drain_and_stats():
    sch = DeadlineScheduler(deadline_ns=100)
    for i in range(5):
        sch.submit(Command.search(1, i), now_ns=0)
    sch.submit(Command.search(2, 9), now_ns=0)
    list(sch.drain())
    assert sch.stats.submitted == 6
    assert sch.stats.max_batch == 5
    assert len(sch) == 0
